"""Wire framing + the Transport seam (DESIGN.md §11).

The paper's deployment is a client/server split: Spark executors talk to an
Alchemist server process over sockets, with scalar metadata in serialized
``Parameters`` frames and matrix payloads in chunked worker-to-worker
transfers (§3.3/§3.5). This module is that boundary for the reproduction:

- **ALWF control frames** — ``b"ALWF" + type(u8) + length(u64)`` followed by
  a hardened ALPK parameter frame (:mod:`repro.core.params`). Every verb of
  the protocol (CONNECT/SEND/RUN/COLLECT/...) is one control frame; replies
  are OK/ERR/ARRAY frames. Malformed bytes surface as
  :class:`~repro.core.errors.ParameterError`, which the server maps to an
  ERR reply instead of crashing its loop.
- **Array framing** — an ARRAY control frame carrying dtype/shape/pad
  metadata, followed by ``__chunks`` length-prefixed raw-byte chunks. The
  encoder hands out ``memoryview`` chunks over the source buffer (zero-copy
  on the send side); the decoder reassembles into one contiguous buffer.
- **The Transport protocol** — extracted from ``ClientCore``'s
  ``_submit_send/_submit_run/_submit_collect/free/barrier`` call sites.
  :class:`LoopbackTransport` routes the in-process path through the same
  array encode/decode, so every existing test doubles as a wire test;
  ``repro.serve.wire.TcpTransport`` speaks the same frames over a localhost
  socket to an :class:`~repro.serve.wire.EngineServer`.

Transport selection: ``connect(transport=...)`` / ``ClientCore(transport=
...)`` take an instance or a name; the ``REPRO_TRANSPORT`` environment
variable (``loopback`` | ``tcp``) sets the default for an entire run, which
is how CI executes the whole tier-1 suite over a real socket.
"""

from __future__ import annotations

import os
import socket
import struct
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import params as params_codec
from repro.core.errors import ParameterError, SessionError, TaskError
from repro.core.futures import AlFuture

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.client import ClientCore
    from repro.core.session import Session

WIRE_MAGIC = b"ALWF"
_HEADER = struct.Struct("<4sBQ")

#: Frame-format version, carried in HELLO/CONNECT. v2 (PR 9) added
#: rid-correlated multi-in-flight replies and shard-aligned array framing;
#: a v1 client greeting a v2 server gets a typed ERR naming both versions
#: (never garbage), because the server checks this before anything else.
WIRE_VERSION = 2

# Control-frame types (requests).
T_HELLO = 0x01
T_CONNECT = 0x02
T_SEND = 0x03
T_RUN = 0x04
T_COLLECT = 0x05
T_FETCH = 0x06
T_FREE = 0x07
T_BARRIER = 0x08
T_REGISTER = 0x09
T_CLOSE = 0x0A
T_HEALTH = 0x0B
# Replies.
T_OK = 0x20
T_ERR = 0x21
T_ARRAY = 0x22

FRAME_NAMES = {
    T_HELLO: "HELLO", T_CONNECT: "CONNECT", T_SEND: "SEND", T_RUN: "RUN",
    T_COLLECT: "COLLECT", T_FETCH: "FETCH", T_FREE: "FREE",
    T_BARRIER: "BARRIER", T_REGISTER: "REGISTER", T_CLOSE: "CLOSE",
    T_HEALTH: "HEALTH",
    T_OK: "OK", T_ERR: "ERR", T_ARRAY: "ARRAY",
}

# Array payloads cross in bounded chunks so neither side ever materializes
# a second full copy for framing (and a reader can account progress).
CHUNK_BYTES = 1 << 20

MAX_FRAME_BYTES = 1 << 24  # control frames are metadata; 16 MiB is hostile


# -- control frames ----------------------------------------------------------
def pack_frame(ftype: int, payload: Dict[str, Any]) -> bytes:
    body = params_codec.pack(payload)
    return _HEADER.pack(WIRE_MAGIC, ftype, len(body)) + body


def unpack_frame(buf: bytes) -> Tuple[int, Dict[str, Any]]:
    if len(buf) < _HEADER.size:
        raise ParameterError(f"truncated ALWF frame header ({len(buf)} bytes)")
    magic, ftype, n = _HEADER.unpack_from(buf, 0)
    if magic != WIRE_MAGIC:
        raise ParameterError("bad magic — not an ALWF wire frame")
    body = buf[_HEADER.size :]
    if len(body) != n:
        raise ParameterError(f"ALWF frame declares {n} payload bytes, has {len(body)}")
    return ftype, params_codec.unpack(body)


# -- socket helpers ----------------------------------------------------------
def recv_exact(sock: socket.socket, n: int) -> memoryview:
    """Read exactly ``n`` bytes or raise ConnectionError on EOF."""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        r = sock.recv_into(view[got:], n - got)
        if r == 0:
            raise ConnectionError(f"peer closed mid-frame ({got}/{n} bytes)")
        got += r
    return memoryview(buf)


def send_frame(sock: socket.socket, ftype: int, payload: Dict[str, Any]) -> int:
    data = pack_frame(ftype, payload)
    sock.sendall(data)
    return len(data)


# sendmsg iovec arrays are capped (IOV_MAX, typically 1024); stay far under
# it so one vectored write never has to be split by the kernel's limit.
_IOV_GROUP = 64


def sendmsg_all(sock: socket.socket, buffers: Sequence[Any], counters: Optional[Dict[str, int]] = None) -> int:
    """Write ``buffers`` with as few syscalls as possible (writev-style).

    Coalesces header + length prefixes + payload chunks into vectored
    ``sendmsg`` calls, looping on partial sends; falls back to ``sendall``
    per buffer where ``sendmsg`` is unavailable. ``counters`` (when given)
    gets its ``"vectored_writes"`` key bumped once per syscall batch."""
    views = [v for v in (memoryview(b).cast("B") for b in buffers) if v.nbytes]
    total = sum(v.nbytes for v in views)
    if not hasattr(sock, "sendmsg"):  # pragma: no cover - non-POSIX sockets
        for v in views:
            sock.sendall(v)
        return total
    for i in range(0, len(views), _IOV_GROUP):
        group = views[i : i + _IOV_GROUP]
        while group:
            sent = sock.sendmsg(group)
            if counters is not None:
                counters["vectored_writes"] = counters.get("vectored_writes", 0) + 1
            while group and sent >= group[0].nbytes:
                sent -= group[0].nbytes
                group = group[1:]
            if group and sent:  # partial write landed inside a view
                group[0] = group[0][sent:]
    return total


def recv_frame(sock: socket.socket) -> Tuple[int, Dict[str, Any], int]:
    """Read one control frame; returns (type, payload, framed bytes)."""
    head = recv_exact(sock, _HEADER.size)
    magic, ftype, n = _HEADER.unpack_from(head, 0)
    if magic != WIRE_MAGIC:
        raise ParameterError("bad magic — not an ALWF wire frame")
    if n > MAX_FRAME_BYTES:
        raise ParameterError(f"ALWF control frame declares {n} bytes (cap {MAX_FRAME_BYTES})")
    body = recv_exact(sock, n) if n else memoryview(b"")
    return ftype, params_codec.unpack(body), _HEADER.size + n


# -- array framing -----------------------------------------------------------
def array_header(arr, pads: Tuple[int, int] = (0, 0), geom=None) -> Dict[str, Any]:
    """Metadata frame for a 2D payload: dtype/shape describe the physical
    bytes on the wire; ``pads`` lets a sender ship a padded physical block
    whose receiver strips back to logical shape (DESIGN.md §7 padded sends).
    With ``geom`` (a :class:`~repro.core.relayout.ShardGeometry`) the frame
    declares shard-aligned chunking: ``__shards``/``__srows`` let the
    receiver decode each chunk straight into a per-shard staging slab."""
    meta = {
        "__rows": int(arr.shape[0]),
        "__cols": int(arr.shape[1]),
        "__dtype": np.dtype(arr.dtype).name,
        "__nbytes": int(arr.nbytes),
        "__pad_r": int(pads[0]),
        "__pad_c": int(pads[1]),
        "__chunks": max(1, -(-arr.nbytes // CHUNK_BYTES)) if arr.nbytes else 0,
    }
    if geom is not None:
        meta["__shards"] = int(geom.n_shards)
        meta["__srows"] = int(geom.shard_rows)
        meta["__chunks"] = sum(
            -(-geom.logical_bytes(j) // CHUNK_BYTES) for j in range(geom.n_shards)
        )
    return meta


def array_chunks(arr: np.ndarray, geom=None) -> List[memoryview]:
    """Zero-copy chunk views over the array's contiguous bytes. With ``geom``
    the chunk boundaries additionally break at shard-slab boundaries, so no
    chunk ever spans two destination shards (the stream is the same logical
    bytes either way — slabs are contiguous in row-major order)."""
    data = memoryview(np.ascontiguousarray(arr)).cast("B")
    if geom is None:
        return [data[i : i + CHUNK_BYTES] for i in range(0, len(data), CHUNK_BYTES)] or []
    itemsize, cols = geom.itemsize, arr.shape[1]
    chunks: List[memoryview] = []
    for s, e in geom.intervals:
        lo, hi = s * cols * itemsize, e * cols * itemsize
        chunks.extend(data[i : min(i + CHUNK_BYTES, hi)] for i in range(lo, hi, CHUNK_BYTES))
    return chunks


def encode_array(
    arr: np.ndarray, pads: Tuple[int, int] = (0, 0), geom=None
) -> Tuple[bytes, List[memoryview], int]:
    """(header frame, chunk views, total framed bytes) for one payload."""
    header = pack_frame(T_ARRAY, array_header(arr, pads, geom))
    chunks = array_chunks(arr, geom)
    framed = len(header) + sum(8 + len(c) for c in chunks)
    return header, chunks, framed


def decode_array(meta: Dict[str, Any], data) -> np.ndarray:
    """Inverse of :func:`encode_array` given the chunk bytes.

    ``data`` may be ``bytes``, ``bytearray``, or a ``memoryview`` — bytearray
    and memoryview input decode zero-copy (``np.frombuffer`` wraps the buffer
    in place), which is what keeps the loopback path and the receive side
    free of an extra contiguous copy for multi-chunk arrays."""
    try:
        dtype = np.dtype(meta["__dtype"])
    except (TypeError, KeyError) as exc:
        raise ParameterError(f"bad array frame dtype: {exc}") from None
    rows, cols = int(meta["__rows"]), int(meta["__cols"])
    nbytes = data.nbytes if isinstance(data, memoryview) else len(data)
    if rows * cols * dtype.itemsize != nbytes:
        raise ParameterError(
            f"array frame declares {rows}x{cols} {dtype.name} "
            f"({rows * cols * dtype.itemsize} bytes), got {nbytes} payload bytes"
        )
    arr = np.frombuffer(data, dtype=dtype).reshape(rows, cols)
    pr, pc = int(meta.get("__pad_r") or 0), int(meta.get("__pad_c") or 0)
    if pr or pc:
        arr = arr[: rows - pr, : cols - pc]
    return arr


def send_array(
    sock: socket.socket,
    arr: np.ndarray,
    pads: Tuple[int, int] = (0, 0),
    geom=None,
    counters: Optional[Dict[str, int]] = None,
) -> int:
    """Frame + stream one array: header, then length-prefixed chunks, all
    coalesced into vectored writes (one syscall covers many chunks) instead
    of the two ``sendall`` calls per chunk the v1 wire paid."""
    header, chunks, framed = encode_array(np.asarray(arr), pads, geom)
    bufs: List[Any] = [header]
    for c in chunks:
        bufs.append(struct.pack("<Q", len(c)))
        bufs.append(c)
    sendmsg_all(sock, bufs, counters)
    return framed


def recv_array_body(sock: socket.socket, meta: Dict[str, Any]) -> Tuple[np.ndarray, int]:
    """Chunks following an already-read ARRAY frame → (array, bytes read).

    Decodes in place over the receive buffer (no ``bytes()`` copy): this one
    allocation is the caller's final array, not a reassembly staging copy."""
    nbytes = int(meta["__nbytes"])
    buf = bytearray(nbytes)
    view = memoryview(buf)
    got = 0
    read = 0
    for _ in range(int(meta["__chunks"])):
        (n,) = struct.unpack("<Q", recv_exact(sock, 8))
        if got + n > nbytes:
            raise ParameterError(
                f"array chunks overflow declared size ({got + n} > {nbytes})"
            )
        recv_into(sock, view[got : got + n])
        got += n
        read += 8 + n
    if got != nbytes:
        raise ParameterError(f"array frame short: {got} of {nbytes} payload bytes")
    return decode_array(meta, view), read


def recv_into(sock: socket.socket, view: memoryview) -> None:
    """Fill ``view`` exactly from the socket, or raise ConnectionError."""
    got = 0
    n = view.nbytes
    while got < n:
        r = sock.recv_into(view[got:], n - got)
        if r == 0:
            raise ConnectionError(f"peer closed mid-frame ({got}/{n} bytes)")
        got += r


def recv_array(sock: socket.socket) -> Tuple[np.ndarray, int]:
    ftype, meta, n0 = recv_frame(sock)
    if ftype != T_ARRAY:
        raise ParameterError(f"expected ARRAY frame, got {FRAME_NAMES.get(ftype, ftype)}")
    arr, n1 = recv_array_body(sock, meta)
    return arr, n0 + n1


# -- shard-direct staging (DESIGN.md §13) ------------------------------------
class StagedShards:
    """Receive-side result of a shard-direct stream: per-shard physical host
    slabs (drawn from the governor's staging pool) plus the geometry, with
    the host→device copies possibly already in flight on the transfer ring.

    Quacks enough like the logical ndarray (``shape``/``dtype``/``ndim``/
    ``__array__``) that validation, attach fallbacks, and the content store
    keep working; the send task assembles the sharded device array with
    ``jax.make_array_from_single_device_arrays`` — never a full-array
    reassembly copy. ``content_key()`` streams sha1 over the logical slab
    views for the same reason."""

    ndim = 2

    def __init__(self, geom, buffers: List[np.ndarray], pool=None):
        self.geom = geom
        self.buffers = buffers  # physical (shard_rows, cols) slabs
        self._pool = pool
        self._device: List[Optional[Any]] = [None] * geom.n_shards
        self._events = [None] * geom.n_shards  # threading.Event per eager put
        #: [(start, end)] wall-clock windows of completed device_put jobs and
        #: the socket-read window — the overlap-ratio instrumentation.
        self.put_windows: List[Tuple[float, float]] = []
        self.socket_window: Optional[Tuple[float, float]] = None
        #: Optional fn(staged) invoked once when device_array() completes —
        #: transports hook this to fold overlap/put timings into wire stats.
        self.on_assembled = None
        self._assembled = False

    @property
    def shape(self) -> Tuple[int, int]:
        return self.geom.shape

    @property
    def dtype(self):
        return np.dtype(self.geom.dtype)

    @property
    def nbytes(self) -> int:
        r, c = self.geom.shape
        return r * c * self.geom.itemsize

    def logical_slabs(self) -> List[np.ndarray]:
        """Per-shard views of the logical rows (pad slack excluded)."""
        out = []
        for j, (s, e) in enumerate(self.geom.intervals):
            out.append(self.buffers[j][: e - s])
        return out

    def __array__(self, dtype=None):
        # Materialization fallback (attach payloads, non-staged consumers):
        # the one deliberate full copy, never on the shard-direct hot path.
        full = np.concatenate([s for s in self.logical_slabs() if s.size] or
                              [np.empty((0, self.geom.shape[1]), self.dtype)], axis=0)
        full = full.reshape(self.geom.shape)
        return full.astype(dtype, copy=False) if dtype is not None else full

    def content_key(self) -> Tuple:
        """Streaming equivalent of :func:`repro.core.expr.content_key`: sha1
        over the logical slab bytes in row order, no reassembly copy."""
        import hashlib

        h = hashlib.sha1()
        for slab in self.logical_slabs():
            h.update(np.ascontiguousarray(slab).data)
        r, c = self.geom.shape
        return ((int(r), int(c)), str(self.dtype), h.hexdigest())

    def matches(self, layout, mesh) -> bool:
        return self.geom.matches(layout, mesh)

    # -- device assembly ------------------------------------------------------
    def _put(self, j: int) -> None:
        import time as _time

        import jax

        t0 = _time.perf_counter()
        arr = jax.device_put(self.buffers[j], self.geom.devices[j])
        arr.block_until_ready()
        self._device[j] = arr
        self.put_windows.append((t0, _time.perf_counter()))

    def device_array(self, sharding=None):
        """The staged client-layout device array: waits for in-flight ring
        puts, issues any remaining ones inline, and assembles the shards —
        no host-side reassembly. ``sharding`` is the client-layout sharding
        (callers in ``ClientCore`` pass the real one); absent, an equivalent
        row sharding is rebuilt from the geometry's device order."""
        import jax

        for j in range(self.geom.n_shards):
            ev = self._events[j]
            if ev is not None:
                ev.wait()
            if self._device[j] is None:
                self._put(j)
        if sharding is None:
            import jax.sharding as jsh

            mesh = jsh.Mesh(np.asarray(self.geom.devices), ("data",))
            sharding = jsh.NamedSharding(mesh, jsh.PartitionSpec("data", None))
        by_dev = {d: j for j, d in enumerate(self.geom.devices)}
        arrays = [
            self._device[by_dev[dev]]
            for dev in sharding.addressable_devices_indices_map(self.geom.physical_shape)
        ]
        out = jax.make_array_from_single_device_arrays(
            self.geom.physical_shape, sharding, arrays
        )
        if not self._assembled:
            self._assembled = True
            if self.on_assembled is not None:
                self.on_assembled(self)
        return out

    def overlap_ratio(self) -> Optional[float]:
        """Σ(put ∩ socket window) / Σ(put duration), None before finish()."""
        if self.socket_window is None or not self.put_windows:
            return None
        t0, t1 = self.socket_window
        put = sum(e - s for s, e in self.put_windows)
        if put <= 0:
            return None
        overlap = sum(max(0.0, min(e, t1) - max(s, t0)) for s, e in self.put_windows)
        return overlap / put

    def dispose(self, *check_arrays) -> None:
        """Return slabs to the staging pool — except any aliased by a live
        device array (CPU ``device_put`` is zero-copy; see ``_aliases_host``)."""
        if self._pool is None:
            return
        from repro.core.memgov import _aliases_host

        live = [self._device[j] for j in range(len(self.buffers))]
        live.extend(a for a in check_arrays if a is not None)
        for j, buf in enumerate(self.buffers):
            if buf is None:
                continue
            if any(a is not None and _aliases_host(a, buf) for a in live):
                continue
            self._pool.release(buf)
            self.buffers[j] = buf  # kept readable for logical views
        # slabs stay referenced for logical reads; the pool guards against
        # double-acquire by identity, so a released-but-referenced slab is
        # only rewritten after this object is dropped by its consumer.


class ShardStreamReceiver:
    """Decodes a shard-aligned ARRAY body chunk-by-chunk into per-shard
    staging slabs, optionally firing a ``device_put`` per shard as its bytes
    land (overlapping socket reads with host→device copies).

    ``pool`` is the governor's staging pool (slab reuse across receives);
    ``ring`` a :class:`~repro.core.taskqueue.TransferExecutor` for the eager
    puts — when absent or full, puts run at assembly time instead."""

    def __init__(self, meta: Dict[str, Any], geom, pool=None, ring=None, eager: bool = True):
        import threading as _threading
        import time as _time

        self.geom = geom
        self.meta = meta
        self._ring = ring
        self._eager = eager and geom.shape[0] > 0
        self._threading = _threading
        self._time = _time
        slab = geom.slab_shape()
        dtype = np.dtype(geom.dtype)
        buffers = []
        for j, (s, e) in enumerate(geom.intervals):
            buf = pool.acquire(slab, dtype) if pool is not None else np.empty(slab, dtype)
            filled = e - s
            if filled < geom.shard_rows:
                buf[filled:] = 0  # pad slack: the fused-into-decode zero fill
            buffers.append(buf)
        self.staged = StagedShards(geom, buffers, pool=pool)
        self._shard = 0
        self._offset = 0  # bytes filled into the current shard's logical slab
        self._t0: Optional[float] = None
        self.read = 0

    def _advance_full_shards(self) -> None:
        while self._shard < self.geom.n_shards:
            want = self.geom.logical_bytes(self._shard)
            if self._offset < want:
                return
            self._complete(self._shard)
            self._shard += 1
            self._offset = 0

    def _complete(self, j: int) -> None:
        if not self._eager:
            return
        ev = self._threading.Event()
        self.staged._events[j] = ev

        def job(jj=j, ee=ev):
            try:
                self.staged._put(jj)
            finally:
                ee.set()

        if self._ring is None or not self._ring.try_submit(job):
            job()  # ring full: copy on this thread (still inside the window)

    def slab_view(self, n: int) -> memoryview:
        """A writable view of the next ``n`` bytes of the current shard's
        slab. Raises if the chunk would cross a shard boundary — the sender's
        framing contract."""
        while (
            self._shard < self.geom.n_shards
            and self.geom.logical_bytes(self._shard) == 0
        ):
            self._complete(self._shard)
            self._shard += 1
        if self._shard >= self.geom.n_shards:
            raise ParameterError("array chunks overflow declared shard layout")
        want = self.geom.logical_bytes(self._shard)
        if self._offset + n > want:
            raise ParameterError(
                f"chunk crosses shard boundary ({self._offset + n} > {want})"
            )
        buf = memoryview(self.staged.buffers[self._shard]).cast("B")
        return buf[self._offset : self._offset + n]

    def feed(self, data) -> None:
        """Decode one chunk (bytes/memoryview) into the staging slabs."""
        view = memoryview(data).cast("B")
        if self._t0 is None:
            self._t0 = self._time.perf_counter()
        self.slab_view(view.nbytes)[:] = view
        self._offset += view.nbytes
        self.read += view.nbytes
        self._advance_full_shards()

    def recv_body(self, sock: socket.socket) -> int:
        """Read the full shard-aligned body from ``sock`` (length-prefixed
        chunks, as framed by :func:`encode_array` with a geometry); returns
        framed bytes read."""
        if self._t0 is None:
            self._t0 = self._time.perf_counter()
        read = 0
        for _ in range(int(self.meta["__chunks"])):
            (n,) = struct.unpack("<Q", recv_exact(sock, 8))
            target = self.slab_view(n)
            recv_into(sock, target)
            self._offset += n
            read += 8 + n
            self.read += n
            self._advance_full_shards()
        self.finish()
        return read

    def finish(self) -> StagedShards:
        self._advance_full_shards()
        if self._shard < self.geom.n_shards or self._offset:
            self.abort()
            raise ParameterError(
                f"shard stream short: stopped in shard {self._shard} "
                f"of {self.geom.n_shards}"
            )
        t0 = self._t0 if self._t0 is not None else self._time.perf_counter()
        self.staged.socket_window = (t0, self._time.perf_counter())
        return self.staged

    def abort(self) -> None:
        """Mid-stream failure: hand unconsumed slabs straight back to the
        pool (shards already claimed by an eager put are left to the GC —
        their device arrays may alias the slab)."""
        pool = self.staged._pool
        if pool is None:
            return
        for j, buf in enumerate(self.staged.buffers):
            if self.staged._events[j] is None and self.staged._device[j] is None:
                pool.release(buf)


# -- error mapping -----------------------------------------------------------
def error_payload(exc: BaseException) -> Dict[str, Any]:
    return {"__etype": type(exc).__name__, "__emsg": str(exc)}


def exception_from_payload(payload: Dict[str, Any]) -> BaseException:
    """Reconstruct a wire error client-side: Alchemist errors by class name
    (their constructors are message-only by design), builtins likewise, and
    anything else degrades to TaskError carrying the original type name."""
    import builtins

    from repro.core import errors as errors_mod

    etype = str(payload.get("__etype") or "TaskError")
    msg = str(payload.get("__emsg") or "")
    cls = getattr(errors_mod, etype, None)
    if isinstance(cls, type) and issubclass(cls, errors_mod.AlchemistError):
        return cls(msg)
    bcls = getattr(builtins, etype, None)
    if isinstance(bcls, type) and issubclass(bcls, Exception):
        try:
            return bcls(msg)
        except TypeError:  # exotic constructor signature
            pass
    return TaskError(f"{etype}: {msg}")


# -- run-request framing -----------------------------------------------------
# A RUN request puts every argument through the codec: scalars/strings as
# themselves, matrix handles as HandleRefs, and in-flight futures as integer
# tickets the receiving side maps back through its ticket table.
def encode_run_request(
    library: str,
    routine: str,
    args: Tuple[Any, ...],
    params: Dict[str, Any],
    *,
    block: bool,
    out_shapes: Optional[Sequence] = None,
    out_dtype: Any = None,
    ticket_of=None,
) -> Dict[str, Any]:
    payload: Dict[str, Any] = {
        "__lib": library,
        "__routine": routine,
        "__block": block,
        "__n_args": len(args),
        "__out_dtype": None if out_dtype is None else np.dtype(out_dtype).name,
        "__n_shapes": -1 if out_shapes is None else len(out_shapes),
    }
    if out_shapes is not None:
        for i, s in enumerate(out_shapes):
            payload[f"__shape_{i}"] = None if s is None else [int(d) for d in s]
    for i, a in enumerate(args):
        if isinstance(a, AlFuture):
            if ticket_of is None:
                raise ParameterError(
                    f"run argument {i} is an in-flight future; this transport "
                    "cannot reference it"
                )
            payload[f"__t{i}"] = int(ticket_of(a))
        else:
            payload[f"__a{i}"] = a
    for k, v in params.items():
        if isinstance(v, AlFuture):
            if ticket_of is None:
                raise ParameterError(
                    f"run parameter {k!r} is an in-flight future; this "
                    "transport cannot reference it"
                )
            payload[f"__kt_{k}"] = int(ticket_of(v))
        else:
            payload[f"__kw_{k}"] = v
    return payload


def decode_run_request(
    payload: Dict[str, Any],
    *,
    future_of=None,
    handle_of=None,
) -> Dict[str, Any]:
    """Inverse of :func:`encode_run_request`. ``future_of(ticket)`` maps
    tickets back to futures; ``handle_of(ref)`` may eagerly resolve a
    HandleRef to its live AlMatrix (falling back to the ref itself keeps the
    classic lazy failure-at-execution semantics for unknown handles)."""
    n_args = int(payload["__n_args"])
    args: List[Any] = []
    for i in range(n_args):
        if f"__t{i}" in payload:
            args.append(future_of(int(payload[f"__t{i}"])))
        else:
            args.append(_maybe_handle(payload[f"__a{i}"], handle_of))
    params: Dict[str, Any] = {}
    for k, v in payload.items():
        if k.startswith("__kw_"):
            params[k[len("__kw_") :]] = _maybe_handle(v, handle_of)
        elif k.startswith("__kt_"):
            params[k[len("__kt_") :]] = future_of(int(v))
    n_shapes = int(payload["__n_shapes"])
    out_shapes = None
    if n_shapes >= 0:
        out_shapes = [
            None if payload[f"__shape_{i}"] is None else tuple(payload[f"__shape_{i}"])
            for i in range(n_shapes)
        ]
    out_dtype = payload["__out_dtype"]
    return {
        "library": payload["__lib"],
        "routine": payload["__routine"],
        "args": tuple(args),
        "params": params,
        "block": bool(payload["__block"]),
        "out_shapes": out_shapes,
        "out_dtype": None if out_dtype is None else np.dtype(out_dtype),
    }


def _maybe_handle(v: Any, handle_of) -> Any:
    if handle_of is not None and isinstance(v, params_codec.HandleRef):
        return handle_of(v)
    return v


# -- the Transport seam ------------------------------------------------------
class Transport:
    """Protocol extracted from ClientCore's submission call sites.

    A transport owns *how* the five verbs reach the engine; the engine-side
    semantics live in ``ClientCore._local_*``. Implementations must keep the
    verbs' error surfaces: fail-fast errors (unknown library, bad shapes)
    raise at the call site, execution errors fail the returned future.
    """

    name = "base"

    def open_session(self, core: "ClientCore", kwargs: Dict[str, Any]) -> "Session":
        raise NotImplementedError

    def submit_send(self, core, array, *, name, block, key=None, payload=None) -> AlFuture:
        raise NotImplementedError

    def submit_run(
        self, core, library, routine, args, params, *, block, out_shapes, out_dtype
    ) -> AlFuture:
        raise NotImplementedError

    def submit_collect(self, core, h) -> AlFuture:
        raise NotImplementedError

    def free(self, core, h) -> AlFuture:
        raise NotImplementedError

    def barrier(self, core, timeout: Optional[float]) -> None:
        raise NotImplementedError

    def register_library(self, core, name: str, spec: str):
        raise NotImplementedError

    def close_session(self, core) -> None:
        raise NotImplementedError

    def wire_stats(self) -> Dict[str, int]:
        """Bytes/frames this transport moved (framing included), plus the
        PR-9 data-plane counters: vectored write syscalls, shard-direct vs
        full-reassembly receive paths, and in-flight request depth."""
        return {
            "bytes_sent": 0,
            "bytes_received": 0,
            "frames": 0,
            "vectored_writes": 0,
            "shard_direct_receives": 0,
            "reassembly_receives": 0,
            "inflight": 0,
            "max_inflight": 0,
        }


class LoopbackTransport(Transport):
    """The in-process path, routed through the wire's array framing.

    Send and collect payloads are encoded to frame bytes and decoded back
    before touching the engine — a genuine serialization boundary with zero
    sockets — so every tier-1 test exercises the codec a TCP deployment
    uses, and the recorded frame bytes give the wire benchmark its loopback
    baseline. Control verbs dispatch directly: their codec coverage lives in
    the run task's ALPK round trip (client.py) and in the TCP transport.
    """

    name = "loopback"

    def __init__(self):
        self.bytes_framed = 0
        self.frames = 0
        self.counters: Dict[str, int] = {
            "shard_direct_receives": 0,
            "reassembly_receives": 0,
            "overlap_ns": 0,
            "put_ns": 0,
        }

    def _roundtrip(self, arr: np.ndarray) -> np.ndarray:
        header, chunks, framed = encode_array(arr)
        self.bytes_framed += framed
        self.frames += 1
        ftype, meta = unpack_frame(header)
        assert ftype == T_ARRAY
        # bytearray join keeps the decode zero-copy over this one buffer —
        # it IS the client array, not a reassembly staging copy.
        buf = bytearray()
        for c in chunks:
            buf += c
        return decode_array(meta, buf)

    def open_session(self, core, kwargs):
        return core.engine.connect(**kwargs)

    def _stage(self, core, arr: np.ndarray):
        """Shard-direct framing for the in-process path (DESIGN.md §13):
        encode with shard-aligned chunk boundaries and decode each chunk
        straight into a per-shard staging slab from the governor's pool —
        tier-1 exercises the same streaming decode TCP uses. Returns None
        when the layout has no row-slab geometry (cyclic/col-sharded/...)."""
        from repro.core.relayout import shard_geometry

        sess = getattr(core, "session", None)
        if sess is None:
            return None
        if core.engine_layout.cyclic:
            # Cyclic residency forbids pre-padding (the permutation would
            # interleave the zero rows) — staging slabs are padded, so keep
            # cyclic pipelines on the classic path and its loud failures.
            return None
        geom = shard_geometry(arr.shape, arr.dtype, core.client_layout, sess.mesh)
        if geom is None:
            return None
        # pads stay (0, 0): the stream is the *logical* bytes; the receive
        # side materializes pad slack in the slabs (a fallback decoder that
        # ignores __shards reassembles the logical array unchanged).
        header, chunks, framed = encode_array(arr, geom=geom)
        self.bytes_framed += framed
        self.frames += 1
        ftype, meta = unpack_frame(header)
        assert ftype == T_ARRAY
        mg = sess.memgov
        recv = ShardStreamReceiver(
            meta, geom, pool=mg.staging, ring=mg.transfer_ring(), eager=mg.unbudgeted()
        )
        try:
            for c in chunks:
                recv.feed(c)
            staged = recv.finish()
        except BaseException:
            recv.abort()
            raise
        staged.on_assembled = self._record_overlap
        return staged

    def _record_overlap(self, staged) -> None:
        ratio = staged.overlap_ratio()
        if ratio is None:
            return
        put = sum(e - s for s, e in staged.put_windows)
        self.counters["put_ns"] += int(put * 1e9)
        self.counters["overlap_ns"] += int(ratio * put * 1e9)

    def submit_send(self, core, array, *, name, block, key=None, payload=None):
        arr = np.asarray(array)
        staged = self._stage(core, arr)
        if staged is not None:
            self.counters["shard_direct_receives"] += 1
            return core._local_submit_send(
                staged, name=name, block=block, key=key, payload=payload
            )
        self.counters["reassembly_receives"] += 1
        arr = self._roundtrip(arr)
        return core._local_submit_send(arr, name=name, block=block, key=key, payload=payload)

    def submit_run(self, core, library, routine, args, params, *, block, out_shapes, out_dtype):
        # Direct dispatch: the run task itself drives every scalar through
        # the ALPK codec (see ClientCore._local_submit_run), preserving the
        # classic failure timing — unserializable args fail the future, not
        # the call site.
        return core._local_submit_run(
            library, routine, args, params,
            block=block, out_shapes=out_shapes, out_dtype=out_dtype,
        )

    def submit_collect(self, core, h):
        fut = core._local_submit_collect(h)
        return fut.then(lambda out: self._roundtrip(np.asarray(out)), label="collect:wire")

    def free(self, core, h):
        return core._local_free_async(h)

    def barrier(self, core, timeout):
        core.session.drain(timeout)

    def register_library(self, core, name, spec):
        return core._local_register_library(name, spec)

    def close_session(self, core):
        core.engine.release(core.session)

    def wire_stats(self):
        return {
            "bytes_sent": self.bytes_framed,
            "bytes_received": 0,
            "frames": self.frames,
            "vectored_writes": 0,  # no socket: nothing to coalesce
            "inflight": 0,
            "max_inflight": 0,
            **self.counters,
        }


def resolve_transport(spec: Any, default_env: str = "REPRO_TRANSPORT") -> Transport:
    """``None`` → the ``REPRO_TRANSPORT`` env default (``loopback``);
    a name → a fresh instance; a Transport instance → itself."""
    if spec is None:
        spec = os.environ.get(default_env, "loopback") or "loopback"
    if isinstance(spec, Transport):
        return spec
    if spec == "loopback":
        return LoopbackTransport()
    if spec == "tcp":
        from repro.serve.wire import TcpTransport

        return TcpTransport()
    raise SessionError(
        f"unknown transport {spec!r}; expected 'loopback', 'tcp', or a Transport instance"
    )
