"""Wire framing + the Transport seam (DESIGN.md §11).

The paper's deployment is a client/server split: Spark executors talk to an
Alchemist server process over sockets, with scalar metadata in serialized
``Parameters`` frames and matrix payloads in chunked worker-to-worker
transfers (§3.3/§3.5). This module is that boundary for the reproduction:

- **ALWF control frames** — ``b"ALWF" + type(u8) + length(u64)`` followed by
  a hardened ALPK parameter frame (:mod:`repro.core.params`). Every verb of
  the protocol (CONNECT/SEND/RUN/COLLECT/...) is one control frame; replies
  are OK/ERR/ARRAY frames. Malformed bytes surface as
  :class:`~repro.core.errors.ParameterError`, which the server maps to an
  ERR reply instead of crashing its loop.
- **Array framing** — an ARRAY control frame carrying dtype/shape/pad
  metadata, followed by ``__chunks`` length-prefixed raw-byte chunks. The
  encoder hands out ``memoryview`` chunks over the source buffer (zero-copy
  on the send side); the decoder reassembles into one contiguous buffer.
- **The Transport protocol** — extracted from ``ClientCore``'s
  ``_submit_send/_submit_run/_submit_collect/free/barrier`` call sites.
  :class:`LoopbackTransport` routes the in-process path through the same
  array encode/decode, so every existing test doubles as a wire test;
  ``repro.serve.wire.TcpTransport`` speaks the same frames over a localhost
  socket to an :class:`~repro.serve.wire.EngineServer`.

Transport selection: ``connect(transport=...)`` / ``ClientCore(transport=
...)`` take an instance or a name; the ``REPRO_TRANSPORT`` environment
variable (``loopback`` | ``tcp``) sets the default for an entire run, which
is how CI executes the whole tier-1 suite over a real socket.
"""

from __future__ import annotations

import os
import socket
import struct
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import params as params_codec
from repro.core.errors import ParameterError, SessionError, TaskError
from repro.core.futures import AlFuture

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.client import ClientCore
    from repro.core.session import Session

WIRE_MAGIC = b"ALWF"
_HEADER = struct.Struct("<4sBQ")

# Control-frame types (requests).
T_HELLO = 0x01
T_CONNECT = 0x02
T_SEND = 0x03
T_RUN = 0x04
T_COLLECT = 0x05
T_FETCH = 0x06
T_FREE = 0x07
T_BARRIER = 0x08
T_REGISTER = 0x09
T_CLOSE = 0x0A
# Replies.
T_OK = 0x20
T_ERR = 0x21
T_ARRAY = 0x22

FRAME_NAMES = {
    T_HELLO: "HELLO", T_CONNECT: "CONNECT", T_SEND: "SEND", T_RUN: "RUN",
    T_COLLECT: "COLLECT", T_FETCH: "FETCH", T_FREE: "FREE",
    T_BARRIER: "BARRIER", T_REGISTER: "REGISTER", T_CLOSE: "CLOSE",
    T_OK: "OK", T_ERR: "ERR", T_ARRAY: "ARRAY",
}

# Array payloads cross in bounded chunks so neither side ever materializes
# a second full copy for framing (and a reader can account progress).
CHUNK_BYTES = 1 << 20

MAX_FRAME_BYTES = 1 << 24  # control frames are metadata; 16 MiB is hostile


# -- control frames ----------------------------------------------------------
def pack_frame(ftype: int, payload: Dict[str, Any]) -> bytes:
    body = params_codec.pack(payload)
    return _HEADER.pack(WIRE_MAGIC, ftype, len(body)) + body


def unpack_frame(buf: bytes) -> Tuple[int, Dict[str, Any]]:
    if len(buf) < _HEADER.size:
        raise ParameterError(f"truncated ALWF frame header ({len(buf)} bytes)")
    magic, ftype, n = _HEADER.unpack_from(buf, 0)
    if magic != WIRE_MAGIC:
        raise ParameterError("bad magic — not an ALWF wire frame")
    body = buf[_HEADER.size :]
    if len(body) != n:
        raise ParameterError(f"ALWF frame declares {n} payload bytes, has {len(body)}")
    return ftype, params_codec.unpack(body)


# -- socket helpers ----------------------------------------------------------
def recv_exact(sock: socket.socket, n: int) -> memoryview:
    """Read exactly ``n`` bytes or raise ConnectionError on EOF."""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        r = sock.recv_into(view[got:], n - got)
        if r == 0:
            raise ConnectionError(f"peer closed mid-frame ({got}/{n} bytes)")
        got += r
    return memoryview(buf)


def send_frame(sock: socket.socket, ftype: int, payload: Dict[str, Any]) -> int:
    data = pack_frame(ftype, payload)
    sock.sendall(data)
    return len(data)


def recv_frame(sock: socket.socket) -> Tuple[int, Dict[str, Any], int]:
    """Read one control frame; returns (type, payload, framed bytes)."""
    head = recv_exact(sock, _HEADER.size)
    magic, ftype, n = _HEADER.unpack_from(head, 0)
    if magic != WIRE_MAGIC:
        raise ParameterError("bad magic — not an ALWF wire frame")
    if n > MAX_FRAME_BYTES:
        raise ParameterError(f"ALWF control frame declares {n} bytes (cap {MAX_FRAME_BYTES})")
    body = recv_exact(sock, n) if n else memoryview(b"")
    return ftype, params_codec.unpack(body), _HEADER.size + n


# -- array framing -----------------------------------------------------------
def array_header(arr: np.ndarray, pads: Tuple[int, int] = (0, 0)) -> Dict[str, Any]:
    """Metadata frame for a 2D payload: dtype/shape describe the physical
    bytes on the wire; ``pads`` lets a sender ship a padded physical block
    whose receiver strips back to logical shape (DESIGN.md §7 padded sends)."""
    nchunks = max(1, -(-arr.nbytes // CHUNK_BYTES)) if arr.nbytes else 0
    return {
        "__rows": int(arr.shape[0]),
        "__cols": int(arr.shape[1]),
        "__dtype": np.dtype(arr.dtype).name,
        "__nbytes": int(arr.nbytes),
        "__pad_r": int(pads[0]),
        "__pad_c": int(pads[1]),
        "__chunks": nchunks,
    }


def array_chunks(arr: np.ndarray) -> List[memoryview]:
    """Zero-copy chunk views over the array's contiguous bytes."""
    data = memoryview(np.ascontiguousarray(arr)).cast("B")
    return [data[i : i + CHUNK_BYTES] for i in range(0, len(data), CHUNK_BYTES)] or []


def encode_array(arr: np.ndarray, pads: Tuple[int, int] = (0, 0)) -> Tuple[bytes, List[memoryview], int]:
    """(header frame, chunk views, total framed bytes) for one payload."""
    header = pack_frame(T_ARRAY, array_header(arr, pads))
    chunks = array_chunks(arr)
    framed = len(header) + sum(8 + len(c) for c in chunks)
    return header, chunks, framed


def decode_array(meta: Dict[str, Any], data: bytes) -> np.ndarray:
    """Inverse of :func:`encode_array` given the reassembled chunk bytes."""
    try:
        dtype = np.dtype(meta["__dtype"])
    except (TypeError, KeyError) as exc:
        raise ParameterError(f"bad array frame dtype: {exc}") from None
    rows, cols = int(meta["__rows"]), int(meta["__cols"])
    if rows * cols * dtype.itemsize != len(data):
        raise ParameterError(
            f"array frame declares {rows}x{cols} {dtype.name} "
            f"({rows * cols * dtype.itemsize} bytes), got {len(data)} payload bytes"
        )
    arr = np.frombuffer(data, dtype=dtype).reshape(rows, cols)
    pr, pc = int(meta.get("__pad_r") or 0), int(meta.get("__pad_c") or 0)
    if pr or pc:
        arr = arr[: rows - pr, : cols - pc]
    return arr


def send_array(sock: socket.socket, arr: np.ndarray, pads: Tuple[int, int] = (0, 0)) -> int:
    header, chunks, framed = encode_array(np.asarray(arr), pads)
    sock.sendall(header)
    for c in chunks:
        sock.sendall(struct.pack("<Q", len(c)))
        sock.sendall(c)
    return framed


def recv_array_body(sock: socket.socket, meta: Dict[str, Any]) -> Tuple[np.ndarray, int]:
    """Chunks following an already-read ARRAY frame → (array, bytes read)."""
    nbytes = int(meta["__nbytes"])
    buf = bytearray(nbytes)
    view = memoryview(buf)
    got = 0
    read = 0
    for _ in range(int(meta["__chunks"])):
        (n,) = struct.unpack("<Q", recv_exact(sock, 8))
        if got + n > nbytes:
            raise ParameterError(
                f"array chunks overflow declared size ({got + n} > {nbytes})"
            )
        view[got : got + n] = recv_exact(sock, n)
        got += n
        read += 8 + n
    if got != nbytes:
        raise ParameterError(f"array frame short: {got} of {nbytes} payload bytes")
    return decode_array(meta, bytes(buf)), read


def recv_array(sock: socket.socket) -> Tuple[np.ndarray, int]:
    ftype, meta, n0 = recv_frame(sock)
    if ftype != T_ARRAY:
        raise ParameterError(f"expected ARRAY frame, got {FRAME_NAMES.get(ftype, ftype)}")
    arr, n1 = recv_array_body(sock, meta)
    return arr, n0 + n1


# -- error mapping -----------------------------------------------------------
def error_payload(exc: BaseException) -> Dict[str, Any]:
    return {"__etype": type(exc).__name__, "__emsg": str(exc)}


def exception_from_payload(payload: Dict[str, Any]) -> BaseException:
    """Reconstruct a wire error client-side: Alchemist errors by class name
    (their constructors are message-only by design), builtins likewise, and
    anything else degrades to TaskError carrying the original type name."""
    import builtins

    from repro.core import errors as errors_mod

    etype = str(payload.get("__etype") or "TaskError")
    msg = str(payload.get("__emsg") or "")
    cls = getattr(errors_mod, etype, None)
    if isinstance(cls, type) and issubclass(cls, errors_mod.AlchemistError):
        return cls(msg)
    bcls = getattr(builtins, etype, None)
    if isinstance(bcls, type) and issubclass(bcls, Exception):
        try:
            return bcls(msg)
        except TypeError:  # exotic constructor signature
            pass
    return TaskError(f"{etype}: {msg}")


# -- run-request framing -----------------------------------------------------
# A RUN request puts every argument through the codec: scalars/strings as
# themselves, matrix handles as HandleRefs, and in-flight futures as integer
# tickets the receiving side maps back through its ticket table.
def encode_run_request(
    library: str,
    routine: str,
    args: Tuple[Any, ...],
    params: Dict[str, Any],
    *,
    block: bool,
    out_shapes: Optional[Sequence] = None,
    out_dtype: Any = None,
    ticket_of=None,
) -> Dict[str, Any]:
    payload: Dict[str, Any] = {
        "__lib": library,
        "__routine": routine,
        "__block": block,
        "__n_args": len(args),
        "__out_dtype": None if out_dtype is None else np.dtype(out_dtype).name,
        "__n_shapes": -1 if out_shapes is None else len(out_shapes),
    }
    if out_shapes is not None:
        for i, s in enumerate(out_shapes):
            payload[f"__shape_{i}"] = None if s is None else [int(d) for d in s]
    for i, a in enumerate(args):
        if isinstance(a, AlFuture):
            if ticket_of is None:
                raise ParameterError(
                    f"run argument {i} is an in-flight future; this transport "
                    "cannot reference it"
                )
            payload[f"__t{i}"] = int(ticket_of(a))
        else:
            payload[f"__a{i}"] = a
    for k, v in params.items():
        if isinstance(v, AlFuture):
            if ticket_of is None:
                raise ParameterError(
                    f"run parameter {k!r} is an in-flight future; this "
                    "transport cannot reference it"
                )
            payload[f"__kt_{k}"] = int(ticket_of(v))
        else:
            payload[f"__kw_{k}"] = v
    return payload


def decode_run_request(
    payload: Dict[str, Any],
    *,
    future_of=None,
    handle_of=None,
) -> Dict[str, Any]:
    """Inverse of :func:`encode_run_request`. ``future_of(ticket)`` maps
    tickets back to futures; ``handle_of(ref)`` may eagerly resolve a
    HandleRef to its live AlMatrix (falling back to the ref itself keeps the
    classic lazy failure-at-execution semantics for unknown handles)."""
    n_args = int(payload["__n_args"])
    args: List[Any] = []
    for i in range(n_args):
        if f"__t{i}" in payload:
            args.append(future_of(int(payload[f"__t{i}"])))
        else:
            args.append(_maybe_handle(payload[f"__a{i}"], handle_of))
    params: Dict[str, Any] = {}
    for k, v in payload.items():
        if k.startswith("__kw_"):
            params[k[len("__kw_") :]] = _maybe_handle(v, handle_of)
        elif k.startswith("__kt_"):
            params[k[len("__kt_") :]] = future_of(int(v))
    n_shapes = int(payload["__n_shapes"])
    out_shapes = None
    if n_shapes >= 0:
        out_shapes = [
            None if payload[f"__shape_{i}"] is None else tuple(payload[f"__shape_{i}"])
            for i in range(n_shapes)
        ]
    out_dtype = payload["__out_dtype"]
    return {
        "library": payload["__lib"],
        "routine": payload["__routine"],
        "args": tuple(args),
        "params": params,
        "block": bool(payload["__block"]),
        "out_shapes": out_shapes,
        "out_dtype": None if out_dtype is None else np.dtype(out_dtype),
    }


def _maybe_handle(v: Any, handle_of) -> Any:
    if handle_of is not None and isinstance(v, params_codec.HandleRef):
        return handle_of(v)
    return v


# -- the Transport seam ------------------------------------------------------
class Transport:
    """Protocol extracted from ClientCore's submission call sites.

    A transport owns *how* the five verbs reach the engine; the engine-side
    semantics live in ``ClientCore._local_*``. Implementations must keep the
    verbs' error surfaces: fail-fast errors (unknown library, bad shapes)
    raise at the call site, execution errors fail the returned future.
    """

    name = "base"

    def open_session(self, core: "ClientCore", kwargs: Dict[str, Any]) -> "Session":
        raise NotImplementedError

    def submit_send(self, core, array, *, name, block, key=None, payload=None) -> AlFuture:
        raise NotImplementedError

    def submit_run(
        self, core, library, routine, args, params, *, block, out_shapes, out_dtype
    ) -> AlFuture:
        raise NotImplementedError

    def submit_collect(self, core, h) -> AlFuture:
        raise NotImplementedError

    def free(self, core, h) -> AlFuture:
        raise NotImplementedError

    def barrier(self, core, timeout: Optional[float]) -> None:
        raise NotImplementedError

    def register_library(self, core, name: str, spec: str):
        raise NotImplementedError

    def close_session(self, core) -> None:
        raise NotImplementedError

    def wire_stats(self) -> Dict[str, int]:
        """Bytes/frames this transport moved (framing included)."""
        return {"bytes_sent": 0, "bytes_received": 0, "frames": 0}


class LoopbackTransport(Transport):
    """The in-process path, routed through the wire's array framing.

    Send and collect payloads are encoded to frame bytes and decoded back
    before touching the engine — a genuine serialization boundary with zero
    sockets — so every tier-1 test exercises the codec a TCP deployment
    uses, and the recorded frame bytes give the wire benchmark its loopback
    baseline. Control verbs dispatch directly: their codec coverage lives in
    the run task's ALPK round trip (client.py) and in the TCP transport.
    """

    name = "loopback"

    def __init__(self):
        self.bytes_framed = 0
        self.frames = 0

    def _roundtrip(self, arr: np.ndarray) -> np.ndarray:
        header, chunks, framed = encode_array(arr)
        self.bytes_framed += framed
        self.frames += 1
        ftype, meta = unpack_frame(header)
        assert ftype == T_ARRAY
        return decode_array(meta, b"".join(chunks))

    def open_session(self, core, kwargs):
        return core.engine.connect(**kwargs)

    def submit_send(self, core, array, *, name, block, key=None, payload=None):
        arr = self._roundtrip(np.asarray(array))
        return core._local_submit_send(arr, name=name, block=block, key=key, payload=payload)

    def submit_run(self, core, library, routine, args, params, *, block, out_shapes, out_dtype):
        # Direct dispatch: the run task itself drives every scalar through
        # the ALPK codec (see ClientCore._local_submit_run), preserving the
        # classic failure timing — unserializable args fail the future, not
        # the call site.
        return core._local_submit_run(
            library, routine, args, params,
            block=block, out_shapes=out_shapes, out_dtype=out_dtype,
        )

    def submit_collect(self, core, h):
        fut = core._local_submit_collect(h)
        return fut.then(lambda out: self._roundtrip(np.asarray(out)), label="collect:wire")

    def free(self, core, h):
        return core._local_free_async(h)

    def barrier(self, core, timeout):
        core.session.drain(timeout)

    def register_library(self, core, name, spec):
        return core._local_register_library(name, spec)

    def close_session(self, core):
        core.engine.release(core.session)

    def wire_stats(self):
        return {
            "bytes_sent": self.bytes_framed,
            "bytes_received": 0,
            "frames": self.frames,
        }


def resolve_transport(spec: Any, default_env: str = "REPRO_TRANSPORT") -> Transport:
    """``None`` → the ``REPRO_TRANSPORT`` env default (``loopback``);
    a name → a fresh instance; a Transport instance → itself."""
    if spec is None:
        spec = os.environ.get(default_env, "loopback") or "loopback"
    if isinstance(spec, Transport):
        return spec
    if spec == "loopback":
        return LoopbackTransport()
    if spec == "tcp":
        from repro.serve.wire import TcpTransport

        return TcpTransport()
    raise SessionError(
        f"unknown transport {spec!r}; expected 'loopback', 'tcp', or a Transport instance"
    )
