"""AlchemistEngine — the server: device pool, sessions, admission control.

Paper §2/§3: Alchemist runs as a driver + worker-pool server; a client
application connects, requests a number of workers, and gets a dedicated
worker group. TPU adaptation (DESIGN.md §2): the worker pool is the device
set of a mesh; a worker group is a mesh slice; the socket transfer is a
relayout; ``dlopen`` is import-by-path.

The client side lives in :mod:`repro.core.client` (DESIGN.md §9): the v2
``connect()``/:class:`~repro.core.client.Session`/:class:`AlArray` surface,
with the v1 :class:`~repro.core.client.AlchemistContext` kept as a
deprecation shim over the same transport core.

Since PR 5 allocation is **admission-aware** (DESIGN.md §9): the paper's
"assuming a sufficient number of workers is available" failure mode (§2.4)
becomes a bounded *queue* — ``allocate(queue=True, timeout=...)`` waits for a
worker group to free up instead of failing, raising
:class:`~repro.core.errors.AdmissionTimeout` only when the wait expires — and
placement is **content-affine**: a session that declares the datasets it will
send is placed on the free device block whose resident-store entries
(DESIGN.md §8) those content keys can reuse, with ``memgov.pressure()``
recorded at each admission decision for the :meth:`AlchemistEngine.stats`
snapshot.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.errors import AdmissionTimeout, WorkerAllocationError
from repro.core.expr import content_key
from repro.core.layouts import AXIS_DATA, AXIS_MODEL
from repro.core.memgov import MemoryGovernor
from repro.core.resident import ResidentStore
from repro.core.session import Session


def _near_square_grid(n: int) -> Tuple[int, int]:
    """Largest divisor pair (r, c), r <= sqrt(n) <= c — Elemental's default
    process-grid heuristic."""
    r = int(np.floor(np.sqrt(n)))
    while n % r:
        r -= 1
    return r, n // r


def _dataset_keys(datasets: Sequence[Any]) -> List[Tuple]:
    """Normalize declared datasets to resident-store content keys.

    Accepts precomputed key tuples, host/device arrays (hashed here), and
    deferred send nodes (an :class:`~repro.core.client.AlArray`/LazyMatrix
    over a SendExpr, whose key was computed at graph build). A *derived*
    expression (a routine output) has no content identity until it executes
    — declaring one is rejected rather than silently hashed to garbage."""
    keys: List[Tuple] = []
    for d in datasets:
        if isinstance(d, tuple):
            keys.append(d)
            continue
        node = getattr(d, "expr", None)
        if node is not None:
            node_key = getattr(node, "key", None)
            if node_key:
                keys.append(node_key)
                continue
            raise WorkerAllocationError(
                "declared dataset is a derived expression with no content key; "
                "declare the source array (or its send node) instead"
            )
        if isinstance(d, (np.ndarray, jax.Array)):
            keys.append(content_key(d))
            continue
        raise WorkerAllocationError(
            f"cannot derive a content key from declared dataset {type(d).__name__}"
        )
    return keys


class AlchemistEngine:
    """The Alchemist server: owns the worker (device) pool, hands out
    sessions with dedicated worker-group mesh slices, and holds the two
    engine-scoped services every session shares (DESIGN.md §7/§8):

    - ``memgov`` — the engine-wide memory governor. ``hbm_budget`` caps the
      *combined* resident footprint of all sessions (each session may lower
      the shared ceiling further via a per-session ``hbm_budget``);
    - ``residents`` — the content-addressed resident store that dedups
      byte-identical sends across sessions and migrates uniquely-referenced
      content host-side when its session stops. ``share_residents=False``
      restores the session-scoped baseline (every session ships its own
      copy); ``host_retention_bytes`` bounds migrated-content host memory.
    """

    def __init__(
        self,
        devices: Optional[Sequence[jax.Device]] = None,
        name: str = "alchemist",
        hbm_budget: Optional[int] = None,
        share_residents: bool = True,
        host_retention_bytes: Optional[int] = None,
        async_spill: bool = True,
    ):
        self.name = name
        self.devices: List[jax.Device] = list(devices if devices is not None else jax.devices())
        if not self.devices:
            raise WorkerAllocationError("engine started with an empty device pool")
        self._free: List[jax.Device] = list(self.devices)
        self._lock = threading.Lock()
        # Admission queue (DESIGN.md §9): allocations that cannot be placed
        # now wait on this condition; release()/failed-connect cleanup notify.
        self._admission = threading.Condition(self._lock)
        self._queued = 0  # allocations currently waiting for a worker group
        self.admissions: Dict[str, Any] = {
            "immediate": 0,  # placed without waiting
            "queued": 0,  # placed after waiting in the admission queue
            "timeouts": 0,  # gave up waiting (AdmissionTimeout)
            "affinity_hits": 0,  # placements steered by declared-dataset reuse
            "last_queued_pressure": None,  # memgov.pressure() when a wait began
        }
        self.sessions: Dict[int, Session] = {}
        # async_spill=False restores the synchronous copy-out baseline —
        # benchmarks/overlap_spill.py uses it as the numerics control.
        self.memgov = MemoryGovernor(
            budget=hbm_budget, name=f"{name}-memgov", async_spill=async_spill
        )
        self.residents = ResidentStore(enabled=share_residents, retain_bytes=host_retention_bytes)

    # -- worker allocation ---------------------------------------------------
    @property
    def num_workers(self) -> int:
        return len(self.devices)

    @property
    def available_workers(self) -> int:
        return len(self._free)

    @property
    def queued_connects(self) -> int:
        """Allocation requests currently waiting for admission."""
        return self._queued

    def allocate(
        self,
        num_workers: Optional[int] = None,
        grid: Optional[Tuple[int, int]] = None,
        *,
        datasets: Sequence[Any] = (),
        queue: bool = False,
        timeout: Optional[float] = None,
    ) -> Tuple[Mesh, List[jax.Device]]:
        """Carve a worker group out of the free pool.

        With ``queue=False`` (the v1 default) an unplaceable request raises
        :class:`WorkerAllocationError` immediately. With ``queue=True`` it
        waits — bounded by ``timeout`` seconds — until ``release`` returns
        enough devices, raising :class:`AdmissionTimeout` if the wait
        expires; a request larger than the whole engine still fails fast
        (it can never be placed). ``datasets`` steers placement: among the
        contiguous free blocks that fit, the one whose devices last held the
        declared content keys (DESIGN.md §8) is preferred, so warm
        resident-store entries are reused in place.
        """
        # An explicitly non-positive request can never be placed — fail fast
        # even when queueing (only ``num_workers=None`` on a momentarily
        # empty pool legitimately waits: it means "all free devices").
        if grid is not None and grid[0] * grid[1] <= 0:
            raise WorkerAllocationError(f"requested a {grid[0]}x{grid[1]} grid")
        if num_workers is not None and num_workers <= 0:
            raise WorkerAllocationError(f"requested {num_workers} workers")
        # Hash declared datasets only when affinity can actually apply — the
        # signal is discarded with the store disabled, and content_key reads
        # every byte of every declared array.
        keys = _dataset_keys(datasets) if datasets and self.residents.enabled else []
        deadline = None if timeout is None else time.monotonic() + timeout
        queued = False
        with self._admission:
            # Pin the request size once, at request time. ``num_workers=None``
            # means "all free devices" *as seen now* — on a drained pool it
            # means the whole engine. Re-deriving n at each queue wakeup would
            # degrade a queued all-free request to "the first freed device"
            # (whoever releases one worker ends the wait with n=1).
            if grid is not None:
                r, c = grid
                n = r * c
            elif num_workers is not None:
                n = num_workers
                r, c = _near_square_grid(n)
            else:
                n = len(self._free) if self._free else len(self.devices)
                r, c = _near_square_grid(n)
            try:
                while True:
                    if n > len(self.devices):
                        # Never placeable: fail fast even when queueing.
                        raise WorkerAllocationError(
                            f"requested {n} workers but the engine only has "
                            f"{self.num_workers}"
                        )
                    if 0 < n <= len(self._free):
                        devs = self._pick_block(n, keys)
                        self._free = [d for d in self._free if d not in devs]
                        self.admissions["queued" if queued else "immediate"] += 1
                        break
                    if not queue:
                        raise WorkerAllocationError(
                            f"requested {n} workers but only {len(self._free)} of "
                            f"{self.num_workers} are available"
                        )
                    if not queued:
                        queued = True
                        self._queued += 1
                        # Forecast at queue time — surfaced via stats() so an
                        # operator can see what load queued admissions faced.
                        self.admissions["last_queued_pressure"] = self.memgov.pressure()
                    remaining = None if deadline is None else deadline - time.monotonic()
                    if remaining is not None and remaining <= 0:
                        self.admissions["timeouts"] += 1
                        raise AdmissionTimeout(
                            f"connect queued for {timeout}s waiting for "
                            f"{n} worker(s); {len(self._free)} of "
                            f"{self.num_workers} free"
                        )
                    self._admission.wait(remaining)
            finally:
                if queued:
                    self._queued -= 1
        mesh = Mesh(np.asarray(devs, dtype=object).reshape(r, c), (AXIS_DATA, AXIS_MODEL))
        return mesh, devs

    def _pick_block(self, n: int, keys: Sequence[Tuple]) -> List[jax.Device]:
        """Choose ``n`` devices from the free pool (caller holds the lock).

        Default: the first free block, in canonical device order (contiguous
        worker groups, §2.4). With declared dataset keys and a non-empty
        resident store, contiguous candidate windows are scored by overlap
        with the devices that last held each key's content — the session
        lands where its data is warm (DESIGN.md §9 store-aware placement).
        """
        if keys and self.residents.enabled:
            affinity = self.residents.device_affinity(keys)
            if affinity:
                best_i, best_score = 0, 0
                for i in range(len(self._free) - n + 1):
                    ids = {d.id for d in self._free[i : i + n]}
                    score = sum(len(ids & devs) for devs in affinity)
                    if score > best_score:
                        best_i, best_score = i, score
                if best_score > 0:
                    self.admissions["affinity_hits"] += 1
                return list(self._free[best_i : best_i + n])
        return list(self._free[:n])

    def release(self, session: Session) -> None:
        with self._admission:
            owned = self.sessions.pop(session.id, None) is not None
        # Drain the session's task queue BEFORE the devices go back in the
        # pool: a concurrent connect() must never be handed devices whose old
        # session still has tasks dispatching (disjoint worker groups, §2.4).
        session.close()
        if owned:
            with self._admission:
                # Restore the pool in canonical device order: naive appending
                # fragments the pool across connect/stop cycles, and a later
                # allocate would hand out a scrambled, non-contiguous mesh
                # slice (worker groups should be contiguous blocks).
                free = set(self._free) | set(session.worker_devices)
                self._free = [d for d in self.devices if d in free]
                self._admission.notify_all()  # wake queued connects

    def connect(
        self,
        name: str = "app",
        num_workers: Optional[int] = None,
        grid: Optional[Tuple[int, int]] = None,
        hbm_budget: Optional[int] = None,
        *,
        datasets: Sequence[Any] = (),
        queue: bool = False,
        timeout: Optional[float] = None,
    ) -> Session:
        mesh, devs = self.allocate(
            num_workers, grid, datasets=datasets, queue=queue, timeout=timeout
        )
        try:
            session = Session(
                name=name,
                mesh=mesh,
                worker_devices=devs,
                hbm_budget=hbm_budget,
                memgov=self.memgov,
                residents=self.residents,
            )
        except BaseException:
            # A rejected session (e.g. an invalid budget) must hand its
            # worker group straight back — in canonical order, like release.
            with self._admission:
                free = set(self._free) | set(devs)
                self._free = [d for d in self.devices if d in free]
                self._admission.notify_all()
            raise
        self.sessions[session.id] = session
        return session

    # -- observability -------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """One merged engine snapshot (DESIGN.md §9): the worker pool and
        admission queue, every live session's ``SessionStats``, the
        engine-wide governor (``pressure()``, budget, high water), and the
        resident store. This is what ``benchmarks/run.py --json`` embeds."""
        with self._admission:
            pool = {
                "workers": self.num_workers,
                "available_workers": len(self._free),
                "queued_connects": self._queued,
                "live_sessions": len(self.sessions),
                "admissions": dict(self.admissions),
            }
            sessions = dict(self.sessions)
        mg = self.memgov
        return {
            "engine": pool,
            "sessions": {
                str(sid): {"name": s.name, "workers": s.num_workers, **s.stats.summary()}
                for sid, s in sessions.items()
            },
            "memgov": {
                "pressure": mg.pressure(),
                "used": mg.used,
                "reserved": mg.reserved,
                "high_water": mg.high_water,
                "budget": mg.budget,
            },
            "residents": self.residents.stats(),
        }

    def shutdown(self) -> None:
        """Stop every session and drop engine-wide state (the resident
        store's migrated content and the governor's ledger)."""
        for session in list(self.sessions.values()):
            self.release(session)
        self.residents.clear()
        self.memgov.clear()


# Backwards-compatible re-exports: the client surface lived in this module
# until DESIGN.md §9 split it out. Imported late to keep the module graph
# acyclic (client.py never imports engine.py at runtime).
from repro.core.client import AlchemistContext  # noqa: E402,F401
