"""AlchemistEngine (the server) and AlchemistContext (the ACI, client side).

Paper §2/§3: Alchemist runs as a driver + worker-pool server; a Spark
application connects through the Alchemist-Client Interface, requests a
number of workers, registers the MPI libraries it needs, ships matrices over,
invokes routines by (library, routine) name, and collects results back.

TPU adaptation (DESIGN.md §2): the server's worker pool is the device set of
a mesh; a worker group is a mesh slice; the socket transfer is a relayout;
``dlopen`` is import-by-path. The client-visible API is kept nearly
line-for-line with the paper's Scala listings (§3.3):

    engine = AlchemistEngine()                         # start the server
    ac = AlchemistContext(engine, num_workers=4)       # connect
    ac.register_library("elemental", "repro.linalg.library:ElementalLib")
    al_a = ac.send(A)                                  # RDD -> AlMatrix
    (al_u, s, al_v) = ac.run("elemental", "truncated_svd", al_a, k=20)
    U = ac.collect(al_u)                               # AlMatrix -> RDD
    ac.stop()
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import params as params_codec
from repro.core.errors import LibraryError, SessionError, WorkerAllocationError
from repro.core.handles import AlMatrix
from repro.core.layouts import AXIS_DATA, AXIS_MODEL, GRID, ROW, LayoutSpec
from repro.core.registry import Library, LibrarySpec, load_library
from repro.core.relayout import timed_relayout
from repro.core.session import Session


def _near_square_grid(n: int) -> Tuple[int, int]:
    """Largest divisor pair (r, c), r <= sqrt(n) <= c — Elemental's default
    process-grid heuristic."""
    r = int(np.floor(np.sqrt(n)))
    while n % r:
        r -= 1
    return r, n // r


class AlchemistEngine:
    """The Alchemist server: owns the worker (device) pool, hands out
    sessions with dedicated worker-group mesh slices."""

    def __init__(self, devices: Optional[Sequence[jax.Device]] = None, name: str = "alchemist"):
        self.name = name
        self.devices: List[jax.Device] = list(devices if devices is not None else jax.devices())
        if not self.devices:
            raise WorkerAllocationError("engine started with an empty device pool")
        self._free: List[jax.Device] = list(self.devices)
        self._lock = threading.Lock()
        self.sessions: Dict[int, Session] = {}

    # -- worker allocation ---------------------------------------------------
    @property
    def num_workers(self) -> int:
        return len(self.devices)

    @property
    def available_workers(self) -> int:
        return len(self._free)

    def allocate(
        self, num_workers: Optional[int] = None, grid: Optional[Tuple[int, int]] = None
    ) -> Tuple[Mesh, List[jax.Device]]:
        with self._lock:
            if grid is not None:
                r, c = grid
                n = r * c
            else:
                n = num_workers if num_workers is not None else len(self._free)
                if n <= 0:
                    raise WorkerAllocationError(f"requested {n} workers")
                r, c = _near_square_grid(n)
            if n > len(self._free):
                raise WorkerAllocationError(
                    f"requested {n} workers but only {len(self._free)} of "
                    f"{self.num_workers} are available"
                )
            devs = self._free[:n]
            self._free = self._free[n:]
        mesh = Mesh(np.asarray(devs, dtype=object).reshape(r, c), (AXIS_DATA, AXIS_MODEL))
        return mesh, devs

    def release(self, session: Session) -> None:
        with self._lock:
            if session.id in self.sessions:
                del self.sessions[session.id]
                self._free.extend(session.worker_devices)
        session.close()

    def connect(
        self,
        name: str = "app",
        num_workers: Optional[int] = None,
        grid: Optional[Tuple[int, int]] = None,
    ) -> Session:
        mesh, devs = self.allocate(num_workers, grid)
        session = Session(name=name, mesh=mesh, worker_devices=devs)
        self.sessions[session.id] = session
        return session


class AlchemistContext:
    """The ACI — what the client application imports and talks to."""

    def __init__(
        self,
        engine: AlchemistEngine,
        num_workers: Optional[int] = None,
        *,
        name: str = "app",
        grid: Optional[Tuple[int, int]] = None,
        client_layout: LayoutSpec = ROW,
        engine_layout: LayoutSpec = GRID,
    ):
        self.engine = engine
        self.session = engine.connect(name=name, num_workers=num_workers, grid=grid)
        self.client_layout = client_layout
        self.engine_layout = engine_layout
        self._stopped = False

    # -- libraries -----------------------------------------------------------
    def register_library(self, name: str, spec: LibrarySpec) -> Library:
        """Load a library into this session (the paper's registerLibrary).

        ``spec`` may be a Library instance/class or an import-path string
        ``"repro.linalg.library:ElementalLib"`` — resolved only now, the
        runtime-dynamic-linking analogue.
        """
        self._check()
        lib = load_library(spec)
        if name != lib.name:
            # allow aliasing but keep it explicit in the session table
            lib.name = name
        self.session.libraries[name] = lib
        return lib

    def library(self, name: str) -> Library:
        self._check()
        try:
            return self.session.libraries[name]
        except KeyError:
            raise LibraryError(
                f"library {name!r} not registered in session {self.session.id}; "
                f"registered: {sorted(self.session.libraries)}"
            ) from None

    # -- matrix movement (the bridge) -----------------------------------------
    def send(self, array: Union[jax.Array, np.ndarray], name: str = "") -> AlMatrix:
        """Ship a client-side (row-partitioned) matrix to the engine's grid
        layout and return its handle. The paper's RDD→Alchemist transfer."""
        self._check()
        mesh = self.session.mesh
        x = jnp.asarray(array)
        if x.ndim != 2:
            raise SessionError(f"send() expects a 2D matrix, got shape {tuple(x.shape)}")
        # Stage on the client layout first (rows over all session workers) so
        # the recorded transfer is the genuine ROW->GRID redistribution.
        x = jax.device_put(x, self.client_layout.sharding(mesh))
        out, rec = timed_relayout(
            x, self.engine_layout, mesh, src=self.client_layout, direction="send"
        )
        self.session.stats.record_transfer(rec)
        return self.session.new_handle(out, self.engine_layout, name=name)

    def collect(self, h: AlMatrix) -> jax.Array:
        """Materialize an engine-resident matrix back on the client layout.
        The only path that moves bulk data engine→client (paper §3.3)."""
        self._check()
        live = self.session.resolve(h)
        out, rec = timed_relayout(
            live.data(),
            self.client_layout,
            self.session.mesh,
            src=live.layout,
            direction="receive",
        )
        self.session.stats.record_transfer(rec)
        return out

    def free(self, h: AlMatrix) -> None:
        self.session.free_handle(h)

    # -- routine invocation ----------------------------------------------------
    def run(self, library: str, routine: str, *args: Any, **params: Any) -> Any:
        """Invoke ``library.routine`` on the engine (the paper's ``ac.run``).

        Positional args may be AlMatrix handles (resolved engine-side) or
        plain scalars; keyword params must be scalars/small lists and travel
        through the Parameters codec, exactly like the paper's driver-to-
        driver metadata channel.
        """
        self._check()
        lib = self.library(library)
        sess = self.session

        # Drive every scalar through the wire codec: this is the
        # driver->driver parameter frame of §2.1 (and catches unserializable
        # arguments at the API boundary, as the real system would).
        frame = params_codec.pack(
            {f"__pos_{i}": a for i, a in enumerate(args)} | dict(params)
        )
        decoded = params_codec.unpack(frame)

        call_args = []
        for i, a in enumerate(args):
            v = decoded[f"__pos_{i}"]
            if isinstance(v, params_codec.HandleRef):
                call_args.append(sess.get_handle(v.id).data())
            else:
                call_args.append(v)
        call_kwargs = {
            k: (sess.get_handle(v.id).data() if isinstance(v, params_codec.HandleRef) else v)
            for k, v in decoded.items()
            if not k.startswith("__pos_")
        }

        r = lib.routine(routine)
        if "mesh" in r.signature().parameters:
            call_kwargs["mesh"] = sess.mesh

        t0 = time.perf_counter()
        with sess.mesh:
            result = r.fn(*call_args, **call_kwargs)
        result = jax.block_until_ready(result)
        sess.stats.record_compute(time.perf_counter() - t0)

        return self._wrap_outputs(result, f"{library}.{routine}")

    def _wrap_outputs(self, result: Any, label: str) -> Any:
        """Array outputs become engine-resident handles; scalars/vectors are
        non-distributed outputs and return to the driver directly."""
        if isinstance(result, (tuple, list)):
            wrapped = tuple(self._wrap_outputs(r, label) for r in result)
            return type(result)(wrapped) if isinstance(result, list) else wrapped
        if isinstance(result, jax.Array) and result.ndim == 2:
            return self.session.new_handle(result, self.engine_layout, name=label)
        if isinstance(result, jax.Array) and result.ndim <= 1:
            return np.asarray(result)
        return result

    # -- lifecycle ---------------------------------------------------------------
    @property
    def stats(self):
        return self.session.stats

    @property
    def mesh(self) -> Mesh:
        return self.session.mesh

    def stop(self) -> None:
        """Disconnect and release the worker group (paper's ``ac.stop()``)."""
        if not self._stopped:
            self.engine.release(self.session)
            self._stopped = True

    def __enter__(self) -> "AlchemistContext":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def _check(self) -> None:
        if self._stopped:
            raise SessionError("AlchemistContext has been stopped")
