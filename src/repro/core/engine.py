"""AlchemistEngine — the server: device pool, sessions, admission control.

Paper §2/§3: Alchemist runs as a driver + worker-pool server; a client
application connects, requests a number of workers, and gets a dedicated
worker group. TPU adaptation (DESIGN.md §2): the worker pool is the device
set of a mesh; a worker group is a mesh slice; the socket transfer is a
relayout; ``dlopen`` is import-by-path.

The client side lives in :mod:`repro.core.client` (DESIGN.md §9): the v2
``connect()``/:class:`~repro.core.client.Session`/:class:`AlArray` surface,
with the v1 :class:`~repro.core.client.AlchemistContext` kept as a
deprecation shim over the same transport core.

Since PR 8 all admission flows through the unified placement scheduler
(DESIGN.md §12): callers describe what they need with a declarative
:class:`~repro.core.scheduler.PlacementRequest` (workers, priority, content
affinity, deadline, shareability) and the engine-owned
:class:`~repro.core.scheduler.PlacementScheduler` turns it into a
:class:`~repro.core.scheduler.PlacementTicket` — a FIFO queue entry with
smallest-fit + content-affinity scoring, anti-starvation aging, pressure
watermarks over ``memgov.pressure()``, and refcounted shared worker groups.
The v1 kwargs (``queue=``, ``timeout=``, ``datasets=``) keep working through
a deprecation shim that folds them into a request.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.errors import WorkerAllocationError
from repro.core.expr import content_key
from repro.core.layouts import AXIS_DATA, AXIS_MODEL
from repro.core.memgov import MemoryGovernor
from repro.core.resident import ResidentStore
from repro.core.scheduler import (
    PlacementRequest,
    PlacementScheduler,
    PlacementTicket,
    near_square_grid as _near_square_grid,  # noqa: F401  (legacy import site)
)
from repro.core.session import Session

# Sentinel distinguishing "kwarg not passed" from an explicit None/() on the
# deprecated v1 admission kwargs.
_UNSET = object()


def _dataset_keys(datasets: Sequence[Any]) -> List[Tuple]:
    """Normalize declared datasets to resident-store content keys.

    Accepts precomputed key tuples, host/device arrays (hashed here), and
    deferred send nodes (an :class:`~repro.core.client.AlArray`/LazyMatrix
    over a SendExpr, whose key was computed at graph build). A *derived*
    expression (a routine output) has no content identity until it executes
    — declaring one is rejected rather than silently hashed to garbage."""
    keys: List[Tuple] = []
    for d in datasets:
        if isinstance(d, tuple):
            keys.append(d)
            continue
        node = getattr(d, "expr", None)
        if node is not None:
            node_key = getattr(node, "key", None)
            if node_key:
                keys.append(node_key)
                continue
            raise WorkerAllocationError(
                "declared dataset is a derived expression with no content key; "
                "declare the source array (or its send node) instead"
            )
        if isinstance(d, (np.ndarray, jax.Array)):
            keys.append(content_key(d))
            continue
        raise WorkerAllocationError(
            f"cannot derive a content key from declared dataset {type(d).__name__}"
        )
    return keys


def _coerce_request(
    placement: Optional[PlacementRequest],
    num_workers: Optional[int] = None,
    grid: Optional[Tuple[int, int]] = None,
    datasets: Any = _UNSET,
    queue: Any = _UNSET,
    timeout: Any = _UNSET,
) -> PlacementRequest:
    """Fold v1 admission kwargs into a :class:`PlacementRequest`.

    ``workers``/``grid`` remain first-class sugar (no warning); the v1
    admission trio (``datasets``/``queue``/``timeout``) warns and maps onto
    ``affinity``/``deadline`` per the DESIGN.md §12 migration table.
    """
    legacy = [
        name
        for name, value in (("datasets", datasets), ("queue", queue), ("timeout", timeout))
        if value is not _UNSET
    ]
    if legacy:
        warnings.warn(
            f"{', '.join(legacy)} kwarg(s) are deprecated; pass "
            "placement=PlacementRequest(affinity=..., deadline=...) instead "
            "(DESIGN.md §12 migration table)",
            DeprecationWarning,
            stacklevel=3,
        )
    if placement is not None:
        if num_workers is not None or grid is not None or legacy:
            raise WorkerAllocationError(
                "pass either placement=PlacementRequest(...) or the legacy "
                "workers/grid/datasets/queue/timeout kwargs, not both"
            )
        return placement
    queue = False if queue is _UNSET else bool(queue)
    timeout = None if timeout is _UNSET else timeout
    datasets = () if datasets is _UNSET else datasets
    # v1 deadline semantics: queue=False fails fast regardless of timeout;
    # queue=True waits for `timeout` seconds (None = indefinitely).
    deadline = (None if timeout is None else float(timeout)) if queue else 0.0
    return PlacementRequest(
        workers=num_workers,
        grid=grid,
        affinity=tuple(datasets),
        deadline=deadline,
    )


class AlchemistEngine:
    """The Alchemist server: owns the worker (device) pool, hands out
    sessions with dedicated worker-group mesh slices, and holds the
    engine-scoped services every session shares (DESIGN.md §7/§8/§12):

    - ``memgov`` — the engine-wide memory governor. ``hbm_budget`` caps the
      *combined* resident footprint of all sessions (each session may lower
      the shared ceiling further via a per-session ``hbm_budget``);
      ``pressure_watermarks=(high, low)`` — fractions of the effective
      budget — additionally gate new private placements on governor
      pressure, with hysteresis (block above high, resume below low);
    - ``residents`` — the content-addressed resident store that dedups
      byte-identical sends across sessions and migrates uniquely-referenced
      content host-side when its session stops. ``share_residents=False``
      restores the session-scoped baseline (every session ships its own
      copy); ``host_retention_bytes`` bounds migrated-content host memory;
    - ``scheduler`` — the unified placement scheduler: FIFO ticket queue
      with smallest-fit + content-affinity scoring, an ``aging_bound``
      anti-starvation barrier, and refcounted shared worker groups.
    """

    def __init__(
        self,
        devices: Optional[Sequence[jax.Device]] = None,
        name: str = "alchemist",
        hbm_budget: Optional[int] = None,
        share_residents: bool = True,
        host_retention_bytes: Optional[int] = None,
        async_spill: bool = True,
        aging_bound: int = 4,
        pressure_watermarks: Optional[Tuple[float, float]] = None,
    ):
        self.name = name
        self.devices: List[jax.Device] = list(devices if devices is not None else jax.devices())
        if not self.devices:
            raise WorkerAllocationError("engine started with an empty device pool")
        self.sessions: Dict[int, Session] = {}
        # async_spill=False restores the synchronous copy-out baseline —
        # benchmarks/overlap_spill.py uses it as the numerics control.
        self.memgov = MemoryGovernor(
            budget=hbm_budget, name=f"{name}-memgov", async_spill=async_spill
        )
        if pressure_watermarks is not None:
            high, low = pressure_watermarks
            self.memgov.set_watermarks(high, low)
        self.residents = ResidentStore(enabled=share_residents, retain_bytes=host_retention_bytes)
        self.scheduler = PlacementScheduler(
            self.devices,
            memgov=self.memgov,
            residents=self.residents,
            aging_bound=aging_bound,
        )
        # Supervision anchors: wall-clock birth for operators, a monotonic
        # origin for drift-free uptime, and a snapshot sequence number so a
        # fleet scraper can reject stale or reordered stats replies.
        self.started_at = time.time()
        self._monotonic_start = time.monotonic()
        self._snapshot_seq = 0

    # -- worker allocation ---------------------------------------------------
    @property
    def num_workers(self) -> int:
        return len(self.devices)

    @property
    def available_workers(self) -> int:
        return len(self.scheduler.free_devices)

    @property
    def queued_connects(self) -> int:
        """Admission tickets currently waiting in the scheduler queue."""
        return self.scheduler.queued

    @property
    def admissions(self) -> Dict[str, Any]:
        """The scheduler's externally-visible admission counters."""
        return self.scheduler.admissions

    @property
    def _free(self) -> List[jax.Device]:
        """Free pool in canonical order (kept readable for legacy probes)."""
        return self.scheduler.free_devices

    def _submit(self, request: PlacementRequest) -> PlacementTicket:
        """Resolve affinity to content keys and queue the request."""
        affinity = request.affinity or ()
        # Hash declared datasets only when affinity can actually apply — the
        # signal is discarded with the store disabled, and content_key reads
        # every byte of every declared array.
        keys = _dataset_keys(affinity) if affinity and self.residents.enabled else []
        return self.scheduler.submit(request, keys=keys)

    def _mesh_for(self, ticket: PlacementTicket) -> Mesh:
        rows, cols = ticket.grid
        return Mesh(
            np.asarray(ticket.devices, dtype=object).reshape(rows, cols),
            (AXIS_DATA, AXIS_MODEL),
        )

    def allocate(
        self,
        num_workers: Optional[int] = None,
        grid: Optional[Tuple[int, int]] = None,
        *,
        datasets: Any = _UNSET,
        queue: Any = _UNSET,
        timeout: Any = _UNSET,
        placement: Optional[PlacementRequest] = None,
    ) -> Tuple[Mesh, List[jax.Device]]:
        """Carve a worker group out of the free pool.

        v2 callers pass ``placement=PlacementRequest(...)``; the positional
        ``num_workers``/``grid`` remain sugar for a fail-fast private request
        and the v1 ``datasets``/``queue``/``timeout`` kwargs warn and fold
        into the request. Raw allocations are always *private* (no shared
        group can outlive an unbound device list) and the caller owns
        returning the devices. Prefer :meth:`connect`, which binds the
        placement to a session for refcounted release.
        """
        request = _coerce_request(placement, num_workers, grid, datasets, queue, timeout)
        if request.allow_shared:
            request = dataclasses.replace(request, allow_shared=False)
        ticket = self._submit(request)
        self.scheduler.orphan(ticket)
        return self._mesh_for(ticket), list(ticket.devices)

    def _pick_block(self, n: int, keys: Sequence[Tuple]) -> List[jax.Device]:
        """Legacy scoring probe: choose ``n`` free devices without consuming
        them (DESIGN.md §12 smallest-fit + content-affinity scoring)."""
        usable_keys = list(keys) if (keys and self.residents.enabled) else []
        return self.scheduler.pick_block(n, usable_keys)

    def release(self, session: Session) -> None:
        owned = self.sessions.pop(session.id, None) is not None
        # Drain the session's task queue BEFORE the devices go back in the
        # pool: a concurrent connect() must never be handed devices whose old
        # session still has tasks dispatching (disjoint worker groups, §2.4).
        session.close()
        if owned:
            # The scheduler drops a group refcount; the pool is restored in
            # canonical device order only when the last reader leaves.
            self.scheduler.release_session(session.id, session.worker_devices)

    def connect(
        self,
        name: str = "app",
        num_workers: Optional[int] = None,
        grid: Optional[Tuple[int, int]] = None,
        hbm_budget: Optional[int] = None,
        *,
        placement: Optional[PlacementRequest] = None,
        datasets: Any = _UNSET,
        queue: Any = _UNSET,
        timeout: Any = _UNSET,
    ) -> Session:
        request = _coerce_request(placement, num_workers, grid, datasets, queue, timeout)
        ticket = self._submit(request)
        try:
            session = Session(
                name=name,
                mesh=self._mesh_for(ticket),
                worker_devices=list(ticket.devices),
                hbm_budget=hbm_budget,
                memgov=self.memgov,
                residents=self.residents,
            )
        except BaseException:
            # A rejected session (e.g. an invalid budget) must hand its
            # placement straight back — refcounted, so a shared join merely
            # drops the reader count.
            self.scheduler.abort(ticket)
            raise
        session.placement = ticket
        self.scheduler.bind(ticket, session.id)
        self.sessions[session.id] = session
        return session

    # -- observability -------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """One merged engine snapshot (DESIGN.md §9/§12): the worker pool and
        admission queue, every live session's ``SessionStats`` (plus its
        resolved placement ticket), the engine-wide governor (``pressure()``,
        budget, high water), the resident store, and the scheduler section
        (queue depth, ticket lifecycle counters, shared groups, scoring
        hits). This is what ``benchmarks/run.py --json`` embeds."""
        self._snapshot_seq += 1
        pool = {
            "workers": self.num_workers,
            "available_workers": self.available_workers,
            "queued_connects": self.queued_connects,
            "live_sessions": len(self.sessions),
            "admissions": dict(self.admissions),
            "started_at": self.started_at,
            "uptime_s": time.monotonic() - self._monotonic_start,
            "snapshot_seq": self._snapshot_seq,
        }
        sessions = dict(self.sessions)
        mg = self.memgov
        return {
            "engine": pool,
            "sessions": {
                str(sid): {
                    "name": s.name,
                    "workers": s.num_workers,
                    "placement": (
                        s.placement.summary() if s.placement is not None else None
                    ),
                    **s.stats.summary(),
                }
                for sid, s in sessions.items()
            },
            "memgov": {
                "pressure": mg.pressure(),
                "used": mg.used,
                "reserved": mg.reserved,
                "high_water": mg.high_water,
                "budget": mg.budget,
            },
            "residents": self.residents.stats(),
            "scheduler": self.scheduler.stats(),
            "wire": self._wire_stats(),
        }

    def _wire_stats(self) -> Dict[str, Any]:
        """The v2 data-plane section (DESIGN.md §13): the engine's wire
        server counters when one is live, zeros otherwise — always the same
        JSON-serializable shape so dashboards key on it unconditionally."""
        from repro.serve.wire import server_for  # lazy: serve imports core

        srv = server_for(self)
        if srv is None:
            return {
                "server": False,
                "inflight": 0,
                "max_inflight": 0,
                "vectored_writes": 0,
                "shard_direct_receives": 0,
                "reassembly_receives": 0,
                "streamed_fetches": 0,
                "gathered_fetches": 0,
                "overlap_ns": 0,
                "put_ns": 0,
                "version_rejects": 0,
                "bytes_in": 0,
                "bytes_out": 0,
            }
        st = srv.stats
        return {
            "server": True,
            "inflight": srv.inflight_depth(),
            "max_inflight": int(st["max_inflight"]),
            "vectored_writes": int(st["vectored_writes"]),
            "shard_direct_receives": int(st["shard_direct_receives"]),
            "reassembly_receives": int(st["reassembly_receives"]),
            "streamed_fetches": int(st["streamed_fetches"]),
            "gathered_fetches": int(st["gathered_fetches"]),
            "overlap_ns": int(st["overlap_ns"]),
            "put_ns": int(st["put_ns"]),
            "version_rejects": int(st["version_rejects"]),
            "bytes_in": int(st["bytes_in"]),
            "bytes_out": int(st["bytes_out"]),
        }

    def shutdown(self) -> None:
        """Stop every session and drop engine-wide state (the resident
        store's migrated content and the governor's ledger)."""
        for session in list(self.sessions.values()):
            self.release(session)
        self.residents.clear()
        self.memgov.clear()


# Backwards-compatible re-exports: the client surface lived in this module
# until DESIGN.md §9 split it out. Imported late to keep the module graph
# acyclic (client.py never imports engine.py at runtime).
from repro.core.client import AlchemistContext  # noqa: E402,F401
