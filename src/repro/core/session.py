"""Sessions — per-client worker groups, handle tables, and transfer stats.

Paper §2.4/§3.2: each connected Spark application gets a dedicated worker
group (its own MPI communicator spanning the Alchemist driver plus the
allocated workers), its own loaded libraries, and its own matrix namespace.
Here a worker group is a **mesh slice**: a contiguous block of the engine's
devices arranged as a ('data','model') grid.

Each session additionally owns (DESIGN.md §3):

- a :class:`~repro.core.taskqueue.TaskQueue` — the single-worker FIFO that
  executes this session's send/run/collect tasks, keeping per-application
  ordering while letting distinct sessions overlap;
- a :class:`~repro.core.relayout.RelayoutPlanCache` — memoized shard
  geometry for repeated same-shape transfers, with hit/miss counters
  surfaced through :class:`SessionStats`.

Two engine-scoped services are *viewed* rather than owned (DESIGN.md §7/§8):

- ``session.memgov`` is the **engine-wide** memory governor — one shared HBM
  byte budget across every connected session; this session's requested
  budget folds into the shared ceiling while it lives;
- ``session.residents`` is the engine's content-addressed
  :class:`~repro.core.resident.ResidentStore`. Store-backed entries in the
  handle table are per-session *placements* that pin store entries; freeing
  one unpins it, and closing the session migrates uniquely-referenced
  content to the host side instead of dropping it.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional

import jax
from jax.sharding import Mesh

from repro.core.errors import HandleError, SessionError
from repro.core import handles as handles_mod
from repro.core.handles import AlMatrix
from repro.core.layouts import LayoutSpec
from repro.core.memgov import MemoryGovernor
from repro.core.registry import Library
from repro.core.relayout import RelayoutPlanCache, TransferRecord
from repro.core.resident import ResidentStore
from repro.core.taskqueue import TaskQueue

_SESSION_IDS = itertools.count(1)


@dataclasses.dataclass
class SessionStats:
    """Send/Compute/Receive accounting — the paper's Table 1 columns."""

    send_bytes: int = 0
    send_seconds: float = 0.0
    recv_bytes: int = 0
    recv_seconds: float = 0.0
    compute_seconds: float = 0.0
    num_sends: int = 0
    num_receives: int = 0
    num_runs: int = 0
    relayout_cache_hits: int = 0
    relayout_cache_misses: int = 0
    # Lazy offload planner counters (DESIGN.md §6): crossings the planner
    # avoided relative to a naive send→run→collect round-trip execution.
    elided_crossings: int = 0  # collect+resend round trips never performed
    resident_reuses: int = 0  # sends satisfied from this session's residents
    planned_ops: int = 0  # routine invocations lowered by the planner
    cse_hits: int = 0  # structurally identical RunExprs memoized (DESIGN.md §8)
    # Engine resident-store counters (DESIGN.md §8): sends satisfied from
    # content another session (or a closed one) already placed on the engine
    # — an attach-only placement, zero bytes over the client bridge.
    cross_session_reuses: int = 0
    # Placement-scheduler counters (DESIGN.md §12): engine-side bytes moved
    # to place this session's attaches, and attaches served as zero-byte
    # views over a shared worker group's existing placement.
    placement_bytes: int = 0
    shared_views: int = 0
    # Memory-governor counters (DESIGN.md §7): budgeted residency.
    spills: int = 0  # resident matrices moved to the pinned host store
    refills: int = 0  # spilled matrices transparently re-placed on device
    spilled_bytes: int = 0  # cumulative bytes spilled to host
    refilled_bytes: int = 0  # cumulative bytes refilled to device
    hbm_high_water: int = 0  # max engine-wide charged bytes seen at a charge
    # Asynchronous data-plane counters (DESIGN.md §10).
    spill_copy_ns: int = 0  # wall ns of async (ring) spill copy-outs
    spill_overlap_ns: int = 0  # of those, ns the queue worker was computing
    transfer_queue_depth: int = 0  # max transfer-ring depth observed at submit
    fused_relayouts: int = 0  # pad/strip ops served by the fused Pallas kernel
    transfers: List[TransferRecord] = dataclasses.field(default_factory=list)

    def record_transfer(self, rec: TransferRecord) -> None:
        self.transfers.append(rec)
        if rec.planned:  # host-store-served transfers never used a plan
            if rec.cache_hit:
                self.relayout_cache_hits += 1
            else:
                self.relayout_cache_misses += 1
        if rec.fused:
            self.fused_relayouts += 1
        if rec.direction == "send":
            self.send_bytes += rec.cost.bytes_total
            self.send_seconds += rec.seconds
            self.num_sends += 1
        else:
            self.recv_bytes += rec.cost.bytes_total
            self.recv_seconds += rec.seconds
            self.num_receives += 1

    def record_compute(self, seconds: float) -> None:
        self.compute_seconds += seconds
        self.num_runs += 1

    def record_elision(self, n: int = 1) -> None:
        self.elided_crossings += n

    def record_resident_reuse(self, n: int = 1) -> None:
        self.resident_reuses += n

    def record_cross_session_reuse(self, n: int = 1) -> None:
        self.cross_session_reuses += n

    def record_placement_bytes(self, nbytes: int) -> None:
        """Engine-side device_put bytes spent placing an attach."""
        self.placement_bytes += int(nbytes)

    def record_shared_view(self, n: int = 1) -> None:
        """An attach served as a zero-byte view over a shared group."""
        self.shared_views += n

    def record_cse_hit(self, n: int = 1) -> None:
        self.cse_hits += n

    def record_planned_op(self, n: int = 1) -> None:
        self.planned_ops += n

    def record_spill(self, nbytes: int) -> None:
        self.spills += 1
        self.spilled_bytes += int(nbytes)

    def record_refill(self, nbytes: int) -> None:
        self.refills += 1
        self.refilled_bytes += int(nbytes)

    def record_hbm_usage(self, used_bytes: int) -> None:
        self.hbm_high_water = max(self.hbm_high_water, int(used_bytes))

    def record_spill_copy(self, wall_ns: int, overlap_ns: int) -> None:
        """One async copy-out finished: ``wall_ns`` of D2H, of which
        ``overlap_ns`` were hidden behind the queue worker's compute."""
        self.spill_copy_ns += int(wall_ns)
        self.spill_overlap_ns += int(overlap_ns)

    def record_transfer_depth(self, depth: int) -> None:
        self.transfer_queue_depth = max(self.transfer_queue_depth, int(depth))

    def record_fused_relayout(self, n: int = 1) -> None:
        self.fused_relayouts += n

    def summary(self) -> Dict[str, Any]:
        return {
            "send_bytes": self.send_bytes,
            "send_seconds": round(self.send_seconds, 6),
            "compute_seconds": round(self.compute_seconds, 6),
            "recv_bytes": self.recv_bytes,
            "recv_seconds": round(self.recv_seconds, 6),
            "num_sends": self.num_sends,
            "num_receives": self.num_receives,
            "num_runs": self.num_runs,
            "relayout_cache_hits": self.relayout_cache_hits,
            "relayout_cache_misses": self.relayout_cache_misses,
            "elided_crossings": self.elided_crossings,
            "resident_reuses": self.resident_reuses,
            "cross_session_reuses": self.cross_session_reuses,
            "placement_bytes": self.placement_bytes,
            "shared_views": self.shared_views,
            "cse_hits": self.cse_hits,
            "planned_ops": self.planned_ops,
            "spills": self.spills,
            "refills": self.refills,
            "spilled_bytes": self.spilled_bytes,
            "refilled_bytes": self.refilled_bytes,
            "hbm_high_water": self.hbm_high_water,
            "spill_copy_ns": self.spill_copy_ns,
            "spill_overlap_ns": self.spill_overlap_ns,
            "transfer_queue_depth": self.transfer_queue_depth,
            "fused_relayouts": self.fused_relayouts,
        }


class Session:
    """One client application's state on the engine."""

    def __init__(
        self,
        name: str,
        mesh: Mesh,
        worker_devices: List[jax.Device],
        hbm_budget: Optional[int] = None,
        memgov: Optional[MemoryGovernor] = None,
        residents: Optional[ResidentStore] = None,
    ):
        self.id = next(_SESSION_IDS)
        self.name = name
        self.mesh = mesh
        self.worker_devices = worker_devices
        # The resolved PlacementTicket (DESIGN.md §12), set by
        # AlchemistEngine.connect; None for sessions built without a
        # scheduler (unit tests, standalone).
        self.placement = None
        self.handles: Dict[int, AlMatrix] = {}
        self.libraries: Dict[str, Library] = {}
        # name -> import-path spec ("pkg.mod:Class") for every library whose
        # registration is wire-expressible — the re-admission record a fleet
        # recovery needs to rebuild the library table on another engine.
        self.library_specs: Dict[str, str] = {}
        self.stats = SessionStats()
        # The engine-wide governor (one shared budget across sessions); a
        # private one is built only for standalone/unit-test sessions.
        # Attached before the task queue exists: a rejected budget must fail
        # the constructor without leaving a live worker thread behind.
        self._owns_memgov = memgov is None
        self.memgov = memgov if memgov is not None else MemoryGovernor(name=f"memgov-{self.id}")
        self.memgov.attach_session(self, hbm_budget=hbm_budget)
        self.tasks = TaskQueue(name=f"session-{self.id}")
        self.relayout_cache = RelayoutPlanCache()
        # The engine's content-addressed resident store (None when this
        # session was built without an engine).
        self.residents = residents
        self.closed = False

    # -- handle table -------------------------------------------------------
    def new_handle(
        self,
        data: jax.Array,
        layout: LayoutSpec,
        name: str = "",
    ) -> AlMatrix:
        """Register an already-resident array (a routine output: born
        unpadded, so logical shape == physical shape — padded sends go
        through new_pending_handle + materialize(pads=...) instead) and
        charge it against the engine's HBM budget."""
        self._check_open()
        h = AlMatrix(
            shape=tuple(data.shape),
            dtype=data.dtype,
            layout=layout,
            session_id=self.id,
            name=name,
            _data=data,
        )
        self.handles[h.id] = h
        self.memgov.charge(h)
        return h

    def new_pending_handle(
        self,
        shape,
        dtype,
        layout: LayoutSpec,
        name: str = "",
    ) -> AlMatrix:
        """Register a handle whose data a queued task will materialize.

        Metadata (shape/dtype/layout) is known immediately — the paper's
        AlMatrix proxies carry exactly this before any bytes move — so the
        client can pack the handle into parameter frames and chain further
        async calls without waiting for the transfer.
        """
        self._check_open()
        h = AlMatrix(
            shape=tuple(int(d) for d in shape),
            dtype=jax.numpy.dtype(dtype),
            layout=layout,
            session_id=self.id,
            name=name,
            _state=handles_mod.PENDING,
            _governor=self.memgov,
        )
        self.handles[h.id] = h
        return h

    def get_handle(self, handle_id: int) -> AlMatrix:
        self._check_open()
        try:
            return self.handles[handle_id]
        except KeyError:
            raise HandleError(
                f"session {self.id} has no AlMatrix with id {handle_id}"
            ) from None

    def resolve(self, h: AlMatrix) -> AlMatrix:
        """Validate a client-held handle belongs to this session and is live."""
        self._check_open()
        if h.session_id != self.id:
            raise HandleError(
                f"AlMatrix {h.id} belongs to session {h.session_id}, not {self.id} "
                "(handles are not shareable across applications)"
            )
        if h.id not in self.handles:
            raise HandleError(f"AlMatrix {h.id} is not registered in session {self.id}")
        return self.handles[h.id]

    def free_handle(self, h: AlMatrix) -> None:
        live = self.resolve(h)
        live.free()
        if live.store_key is not None and self.residents is not None:
            # An explicit free unpins the store entry; with its last pin the
            # content is gone for good (unlike a close, which migrates).
            self.residents.release(live.store_key, self.id, live)
        del self.handles[live.id]

    # -- lifecycle ----------------------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> None:
        """Barrier: wait until every queued task of this session finished."""
        self.tasks.barrier(timeout)

    def close(self) -> None:
        if self.closed:
            return
        self.tasks.close(wait=True, timeout=60.0)
        # Store-backed placements first: uniquely-referenced content migrates
        # to the host side (DESIGN.md §8) instead of dying with the session.
        if self.residents is not None:
            self.residents.detach_session(self)
        for h in list(self.handles.values()):
            if h.state != handles_mod.FREED:
                h.free()
        self.handles.clear()
        self.libraries.clear()
        if self._owns_memgov:
            self.memgov.clear()
        self.memgov.detach_session(self.id)
        self.closed = True

    def _check_open(self) -> None:
        if self.closed:
            raise SessionError(f"session {self.id} ({self.name!r}) is closed")

    @property
    def num_workers(self) -> int:
        return len(self.worker_devices)

    def descriptor(self) -> Dict[str, Any]:
        """JSON-serializable re-admission record (DESIGN.md §14).

        Everything a fleet recovery needs to re-admit this session on
        another engine through the queued ``connect(placement=...)`` path:
        the placement shape actually granted (workers/grid/priority) and the
        wire-expressible library specs. Data and computation are
        deliberately absent — residents travel by content key through the
        store, and lost outputs re-enter via lineage replay of the client's
        expr DAG.
        """
        t = self.placement
        return {
            "session_id": int(self.id),
            "name": self.name,
            "workers": int(self.num_workers),
            "grid": [int(d) for d in self.mesh.devices.shape],
            "priority": int(t.priority) if t is not None else 0,
            "allow_shared": bool(t.allow_shared) if t is not None else True,
            "libraries": dict(self.library_specs),
        }

    def __repr__(self) -> str:
        return (
            f"Session(id={self.id}, name={self.name!r}, workers={self.num_workers}, "
            f"grid={tuple(self.mesh.devices.shape)}, handles={len(self.handles)})"
        )
