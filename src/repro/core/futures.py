"""AlFuture — deferred results from the asynchronous task-queue engine.

DESIGN.md §4: every asynchronous ACI call (``send_async`` / ``run_async`` /
``collect_async``) returns an :class:`AlFuture` immediately; the actual work
is executed by the owning session's task-queue worker. A future resolves to
whatever the synchronous call would have returned — an :class:`AlMatrix`
handle, a tuple of handles, a scalar, or a client-side array.

Futures are *transparently composable*: passing an unresolved AlFuture back
into ``run_async``/``collect_async``/``free`` is legal — the engine resolves
it at task-execution time. Because each session's queue is FIFO with a single
worker, a future produced by an earlier task of the same session is always
resolved by the time a later task of that session executes, so chained
pipelines (``h = send_async(a); g = run_async('lib', 'gemm', h, h)``) never
stall the worker.

Task failures propagate: the exception raised inside the task is stored and
re-raised from :meth:`AlFuture.result`, so the synchronous wrappers (which
are just ``submit + result()``) keep their original error surface.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional

from repro.core.errors import TaskError

PENDING = "pending"
RESOLVED = "resolved"
FAILED = "failed"


class AlFuture:
    """A deferred ACI result, resolved by a session's task-queue worker."""

    def __init__(self, label: str = ""):
        self.label = label
        self._event = threading.Event()
        self._state = PENDING
        self._value: Any = None
        self._exception: Optional[BaseException] = None
        self._callbacks: List[Callable[["AlFuture"], None]] = []
        self._lock = threading.Lock()

    # -- client side ---------------------------------------------------------
    @property
    def state(self) -> str:
        return self._state

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block until the task ran; return its value or re-raise its error."""
        if not self._event.wait(timeout):
            raise TaskError(
                f"AlFuture {self.label or hex(id(self))} not resolved within {timeout}s"
            )
        if self._state == FAILED:
            raise self._exception
        return self._value

    def exception(self, timeout: Optional[float] = None) -> Optional[BaseException]:
        """Block until done; return the task's exception (None on success)."""
        if not self._event.wait(timeout):
            raise TaskError(
                f"AlFuture {self.label or hex(id(self))} not resolved within {timeout}s"
            )
        return self._exception

    def add_done_callback(self, fn: Callable[["AlFuture"], None]) -> None:
        """Run ``fn(self)`` once resolved (immediately if already done)."""
        with self._lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    def then(self, fn: Callable[[Any], Any], label: str = "") -> "AlFuture":
        """Derived future: resolves to ``fn(result)`` once this one resolves.

        Failure propagates: if this future fails, the derived one fails with
        the same exception (``fn`` never runs); if ``fn`` itself raises, the
        derived future carries that error. The callback runs on whichever
        thread resolves the parent — keep ``fn`` cheap and non-blocking (the
        planner uses it to project one output out of a routine's tuple).
        """
        out = AlFuture(label=label or f"{self.label}:then")

        def _chain(parent: "AlFuture") -> None:
            if parent._state == FAILED:
                out._set_exception(parent._exception)
                return
            try:
                out._set_result(fn(parent._value))
            except BaseException as exc:  # noqa: BLE001 — propagate via future
                out._set_exception(exc)

        self.add_done_callback(_chain)
        return out

    def __await__(self):
        """``await fut`` inside an event loop: the blocking :meth:`result`
        runs in the loop's default executor, so independent futures awaited
        concurrently resolve in parallel — the same unification the v2
        ``AlArray.__await__`` offers (DESIGN.md §9)."""
        import asyncio

        loop = asyncio.get_running_loop()
        return loop.run_in_executor(None, self.result).__await__()

    # -- engine side ---------------------------------------------------------
    def _set_result(self, value: Any) -> None:
        self._finish(RESOLVED, value=value)

    def _set_exception(self, exc: BaseException) -> None:
        self._finish(FAILED, exc=exc)

    def _finish(self, state: str, value: Any = None, exc: Optional[BaseException] = None) -> None:
        with self._lock:
            if self._event.is_set():
                raise TaskError(f"AlFuture {self.label!r} resolved twice")
            self._state = state
            self._value = value
            self._exception = exc
            callbacks, self._callbacks = self._callbacks, []
            self._event.set()
        for fn in callbacks:
            fn(self)

    def __repr__(self) -> str:
        return f"AlFuture({self.label!r}, state={self._state})"


def resolve(obj: Any, timeout: Optional[float] = None) -> Any:
    """Unwrap ``obj`` if it is an AlFuture (recursing through nesting);
    anything else passes through untouched."""
    while isinstance(obj, AlFuture):
        obj = obj.result(timeout)
    return obj


def resolve_tree(obj: Any, timeout: Optional[float] = None) -> Any:
    """:func:`resolve`, applied through tuples/lists/dicts (one level of the
    structures the ACI actually returns)."""
    obj = resolve(obj, timeout)
    if isinstance(obj, (tuple, list)):
        return type(obj)(resolve_tree(o, timeout) for o in obj)
    if isinstance(obj, dict):
        return {k: resolve_tree(v, timeout) for k, v in obj.items()}
    return obj
