"""Generate the EXPERIMENTS.md roofline/dry-run tables from results JSON.

  PYTHONPATH=src python -m repro.launch.report \
      --single results/dryrun_single_pod.json \
      --multi results/dryrun_multi_pod.json \
      --hillclimb results/hillclimb.json --out EXPERIMENTS_tables.md
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List


def _gib(b) -> str:
    return f"{b/2**30:.2f}"


def roofline_table(records: List[Dict]) -> str:
    rows = [
        "| arch | shape | compute ms | memory ms | collective ms | bound | "
        "useful-flops | peak GiB/dev | method |",
        "|---|---|---:|---:|---:|---|---:|---:|---|",
    ]
    for r in records:
        if r["skipped"]:
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — | {r['reason'][:60]} |"
            )
            continue
        if not r["ok"]:
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | FAIL | — | — | {r['error'][:60]} |"
            )
            continue
        p = r["report"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {p['compute_seconds']*1e3:.1f} | "
            f"{p['memory_seconds']*1e3:.1f} | {p['collective_seconds']*1e3:.1f} | "
            f"**{p['dominant']}** | {p['useful_flops_ratio']:.2f} | "
            f"{_gib(p.get('argument_bytes', 0) + p.get('temp_bytes', 0))} | "
            f"{p.get('cost_method', '')[:24]} |"
        )
    return "\n".join(rows)


def dryrun_table(records: List[Dict]) -> str:
    rows = [
        "| arch | shape | mesh | status | args GiB | temp GiB "
        "| FLOPs/dev | coll B/dev | compile s |",
        "|---|---|---|---|---:|---:|---:|---:|---:|",
    ]
    for r in records:
        if r["skipped"]:
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| SKIP ({r['reason'][:48]}) | | | | | |"
            )
            continue
        if not r["ok"]:
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| **FAIL** {r['error'][:48]} | | | | | |"
            )
            continue
        p = r["report"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{_gib(p.get('argument_bytes', 0))} | {_gib(p.get('temp_bytes', 0))} | "
            f"{p['flops_per_device']:.2e} | {p['collective_bytes_per_device']:.2e} | "
            f"{p.get('compile_seconds', 0):.0f} |"
        )
    return "\n".join(rows)


def hillclimb_table(results: Dict) -> str:
    out = []
    for pair, recs in results.items():
        out.append(f"\n#### {pair}\n")
        out.append(
            "| variant | compute ms | memory ms | collective ms | bound | temp GiB "
            "| vs baseline (c/m/coll) |"
        )
        out.append("|---|---:|---:|---:|---|---:|---|")
        for r in recs:
            if not r.get("ok"):
                out.append(f"| {r['variant']} | — | — | — | FAIL | — | {r.get('error','')[:50]} |")
                continue
            vs = (
                f"{r.get('compute_s_vs_base', 1):.2f}/"
                f"{r.get('memory_s_vs_base', 1):.2f}/"
                f"{r.get('collective_s_vs_base', 1):.2f}"
            )
            out.append(
                f"| {r['variant']} | {r['compute_s']*1e3:.0f} | {r['memory_s']*1e3:.0f} | "
                f"{r['collective_s']*1e3:.0f} | {r['dominant']} | "
                f"{r['temp_bytes']/2**30:.1f} | {vs} |"
            )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--single", default=None)
    ap.add_argument("--multi", default=None)
    ap.add_argument("--hillclimb", default=None)
    ap.add_argument("--out", default="EXPERIMENTS_tables.md")
    args = ap.parse_args()

    parts = []
    if args.single:
        recs = json.load(open(args.single))
        parts.append("## §Roofline — single-pod 16x16 (256 chips), per (arch x shape)\n")
        parts.append(roofline_table(recs))
        parts.append("\n\n## §Dry-run — single-pod details\n")
        parts.append(dryrun_table(recs))
    if args.multi:
        recs = json.load(open(args.multi))
        parts.append("\n\n## §Dry-run — multi-pod 2x16x16 (512 chips)\n")
        parts.append(dryrun_table(recs))
    if args.hillclimb:
        parts.append("\n\n## §Perf — hillclimb variants\n")
        parts.append(hillclimb_table(json.load(open(args.hillclimb))))

    with open(args.out, "w") as f:
        f.write("\n".join(parts) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
