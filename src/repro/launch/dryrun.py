import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost analysis and roofline terms.

The two lines above MUST stay first: jax locks the device count at first
initialization, and the production meshes need 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Per combo, the driver:
  1. builds the model with scans fully unrolled (exact cost analysis,
     loop-free HLO for the collective parser),
  2. lowers the right step function (train_step / prefill / serve_step)
     against ShapeDtypeStruct inputs (no allocation),
  3. compiles, prints memory_analysis() (proves it fits) and
     cost_analysis() (FLOPs/bytes for §Roofline),
  4. parses collective traffic from the compiled HLO,
  5. emits a JSON record for EXPERIMENTS.md.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, ArchConfig, InputShape, get_config
from repro.core.sharding import ShardingRules, divisible_spec
from repro.launch.mesh import make_production_mesh
from repro.models.registry import (
    build_model,
    effective_seq,
    input_shardings,
    input_specs,
)
from repro.roofline.analysis import analyze_compiled
from repro.train.optimizer import AdamW
from repro.train.schedule import constant

LM_ARCHS = tuple(a for a in ARCH_IDS if a != "alchemist-svd")


def _named(tree_specs, structs, mesh: Mesh):
    """PartitionSpec tree (+ structs for shapes) -> NamedSharding tree."""
    def one(spec, struct):
        safe = divisible_spec(tuple(struct.shape), spec, mesh)
        return NamedSharding(mesh, safe)

    return jax.tree_util.tree_map(one, tree_specs, structs)


def _logical_to_specs(logical_tree, structs, rules: ShardingRules, mesh: Mesh):
    def one(logical, struct):
        raw = rules.resolve(tuple(logical))
        return divisible_spec(tuple(struct.shape), raw, mesh)

    return jax.tree_util.tree_map(
        one, logical_tree, structs,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


@dataclasses.dataclass
class ComboResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    skipped: bool = False
    reason: str = ""
    report: Optional[Dict[str, Any]] = None
    error: str = ""


def depth_units(cfg: ArchConfig) -> int:
    """The homogeneous scan unit count (layers, or periods for hybrids)."""
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_period
    return cfg.n_layers


def with_depth(cfg: ArchConfig, units: int) -> ArchConfig:
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, n_layers=units * cfg.attn_period)
    if cfg.is_enc_dec:
        return dataclasses.replace(cfg, n_layers=units, encoder_layers=units)
    return dataclasses.replace(cfg, n_layers=units)


def _lower_one(
    cfg: ArchConfig,
    shape: InputShape,
    mesh: Mesh,
    rules: ShardingRules,
    *,
    remat: str,
    unrolled: bool,
):
    """Build + lower the right step function for (cfg, shape); returns lowered."""
    sliding = (
        cfg.sliding_window
        if (shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"))
        else None
    )
    model = build_model(
        cfg, mesh, rules,
        sliding_window=sliding,
        remat=(remat if shape.kind == "train" else "none"),
        scan_unroll=(depth_units(cfg) if unrolled else 1),
    )

    param_structs = model.param_shapes()
    param_specs = model.param_partition_specs()
    param_sh = _named(param_specs, param_structs, mesh)

    batch_structs = input_specs(cfg, shape)
    batch_specs = input_shardings(cfg, shape, rules)
    batch_sh = _named(batch_specs, batch_structs, mesh)

    with mesh:
        if shape.kind == "train":
            opt = AdamW(learning_rate=constant(1e-4), moment_dtype=cfg.optimizer_dtype)
            opt_structs = jax.eval_shape(opt.init, param_structs)
            opt_specs = opt.state_partition_specs(param_specs)
            opt_sh = _named(opt_specs, opt_structs, mesh)

            from repro.train.train_step import make_train_step

            step = make_train_step(model, opt)
            return jax.jit(
                step, in_shardings=(param_sh, opt_sh, batch_sh)
            ).lower(param_structs, opt_structs, batch_structs)

        if shape.kind == "prefill":
            if hasattr(model, "prefill"):
                def fn(p, b):
                    return model.prefill(p, b)
            else:
                def fn(p, b):
                    return model.forward(p, b)
            return jax.jit(fn, in_shardings=(param_sh, batch_sh)).lower(
                param_structs, batch_structs
            )

        # decode
        b = shape.global_batch
        ctx = effective_seq(cfg, shape)
        model_ref = model
        state_structs = jax.eval_shape(lambda: model_ref.init_decode_state(b, ctx))
        logical = model.decode_state_logical()
        state_specs = _logical_to_specs(logical, state_structs, rules, mesh)
        state_sh = jax.tree_util.tree_map(
            lambda s, st: NamedSharding(mesh, divisible_spec(tuple(st.shape), s, mesh)),
            state_specs, state_structs,
        )
        tok_struct = batch_structs["tokens"]
        tok_sh = NamedSharding(
            mesh, divisible_spec(tuple(tok_struct.shape), batch_specs["tokens"], mesh)
        )

        def serve_step(p, state, toks):
            return model_ref.decode_step(p, state, toks)

        return jax.jit(
            serve_step, in_shardings=(param_sh, state_sh, tok_sh)
        ).lower(param_structs, state_structs, tok_struct)


def lower_combo(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    *,
    rules: Optional[ShardingRules] = None,
    remat: str = "full",
    verbose: bool = True,
    costs: bool = True,
) -> ComboResult:
    """Full-config scanned compile (the lowering proof + memory analysis),
    plus — when ``costs`` — two shallow fully-unrolled variants whose exact
    cost analyses give the affine-in-depth fit:

        cost(L) = base + per_layer * L

    which is exact for homogeneous layer stacks (EXPERIMENTS.md §Method).
    """
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_desc = "x".join(str(s) for s in mesh.devices.shape)
    n_dev = mesh.devices.size

    supported, why = cfg.supports_shape(shape)
    if not supported:
        return ComboResult(arch, shape_name, mesh_desc, ok=True, skipped=True, reason=why)

    rules = rules or ShardingRules.default(mesh)

    # 1) the proof: full config, scanned, must lower AND compile
    t0 = time.perf_counter()
    lowered = _lower_one(cfg, shape, mesh, rules, remat=remat, unrolled=False)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    with mesh:
        compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    report = analyze_compiled(
        compiled, cfg=cfg, shape=shape, mesh_desc=mesh_desc, n_devices=n_dev,
        lower_seconds=t_lower, compile_seconds=t_compile,
    )
    rep = report.to_json()
    rep["cost_method"] = "scanned(while-body-once)"

    # 2) exact costs: affine extrapolation from unrolled shallow variants
    #    (attention stubbed; its exact flash-kernel terms re-added analytically
    #     — see repro/roofline/attention_model.py for why)
    if costs:
        from repro.kernels import ops as kernel_ops

        try:
            pts = {}
            stub_attn = shape.kind in ("train", "prefill")
            if stub_attn:
                kernel_ops.ATTENTION_MODE = "stub"
            try:
                for d in (1, 2):
                    vcfg = with_depth(cfg, d)
                    vlow = _lower_one(vcfg, shape, mesh, rules, remat=remat, unrolled=True)
                    with mesh:
                        vcomp = vlow.compile()
                    vrep = analyze_compiled(
                        vcomp, cfg=vcfg, shape=shape, mesh_desc=mesh_desc, n_devices=n_dev
                    )
                    pts[d] = vrep
            finally:
                kernel_ops.ATTENTION_MODE = "real"
            L = depth_units(cfg)

            def fit(attr):
                y1 = getattr(pts[1], attr)
                y2 = getattr(pts[2], attr)
                per = max(y2 - y1, 0.0)
                base = max(y1 - per, 0.0)
                return base + per * L

            from repro.roofline.attention_model import attention_roofline, attention_shards
            from repro.roofline.hw import HW

            flops = fit("flops_per_device")
            hbm = fit("hbm_bytes_per_device")
            coll = fit("collective_bytes_per_device")

            if stub_attn:
                at = attention_roofline(cfg, shape, remat=(remat != "none"))
                bsh, hsh = attention_shards(
                    cfg, tuple(mesh.devices.shape), tuple(mesh.axis_names)
                )
                af, ab = at.per_device(bsh, hsh)
                flops += af
                hbm += ab

            terms = {
                "compute": flops / HW.peak_flops_bf16,
                "memory": hbm / HW.hbm_bandwidth,
                "collective": coll / HW.ici_link_bandwidth,
            }
            dom = max(terms, key=terms.get)
            rep.update(
                flops_per_device=flops,
                hbm_bytes_per_device=hbm,
                collective_bytes_per_device=coll,
                compute_seconds=terms["compute"],
                memory_seconds=terms["memory"],
                collective_seconds=terms["collective"],
                dominant=dom,
                useful_flops_ratio=(
                    rep["model_flops_global"] / (flops * n_dev) if flops else 0.0
                ),
                collectives_by_kind=pts[2].collectives_by_kind,
                cost_method=(
                    "affine-fit(unrolled d=1,2)"
                    + (" + analytic-flash-attention" if stub_attn else "")
                ),
            )
        except Exception as e:  # cost extrapolation is best-effort
            rep["cost_method"] = f"scanned-only (variant fit failed: {type(e).__name__}: {e})"

    if verbose:
        ma = compiled.memory_analysis()
        print(f"--- {arch} x {shape_name} on {mesh_desc} ---")
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"out={ma.output_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB (per device)")
        print(f"  cost[{rep['cost_method']}]: {rep['flops_per_device']:.3e} FLOPs/dev, "
              f"{rep['hbm_bytes_per_device']:.3e} HBM B/dev, "
              f"{rep['collective_bytes_per_device']:.3e} coll B/dev")
        print(f"  roofline: compute={rep['compute_seconds']*1e3:.2f}ms "
              f"memory={rep['memory_seconds']*1e3:.2f}ms "
              f"collective={rep['collective_seconds']*1e3:.2f}ms "
              f"-> {rep['dominant']}-bound; useful-flops={rep['useful_flops_ratio']:.2f}")
        print(f"  lower={t_lower:.1f}s compile={t_compile:.1f}s")
        sys.stdout.flush()
    return ComboResult(arch, shape_name, mesh_desc, ok=True, report=rep)


def main() -> int:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", choices=LM_ARCHS, default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--all", action="store_true", help="run every combo")
    ap.add_argument("--multi-pod", action="store_true", help="(2,16,16) mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat", default="full", choices=("none", "full", "dots"))
    ap.add_argument("--out", default=None, help="write JSON results here")
    ap.add_argument(
        "--no-costs", action="store_true",
        help="skip the unrolled depth-variant cost fit (proof-of-lowering only)",
    )
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(), make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    archs = [args.arch] if args.arch else list(LM_ARCHS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)

    results = []
    failed = 0
    for mesh in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    res = lower_combo(arch, shape, mesh, remat=args.remat, costs=not args.no_costs)
                except Exception as e:  # a failure here is a bug in the system
                    traceback.print_exc()
                    res = ComboResult(
                        arch, shape, "x".join(map(str, mesh.devices.shape)),
                        ok=False, error=f"{type(e).__name__}: {e}",
                    )
                    failed += 1
                if res.skipped:
                    print(f"--- {arch} x {shape} SKIPPED: {res.reason}")
                results.append(dataclasses.asdict(res))

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {len(results)} records to {args.out}")

    n_ok = sum(1 for r in results if r["ok"] and not r["skipped"])
    n_skip = sum(1 for r in results if r["skipped"])
    print(f"dry-run: {n_ok} compiled, {n_skip} skipped, {failed} FAILED")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
