"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 100 --seq 64 --batch 8

On a real TPU deployment, drop --smoke and pass --mesh to pick the
production topology (the process environment provides the devices; this
container runs reduced configs on CPU).
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import INPUT_SHAPES, InputShape, get_config
from repro.core.sharding import single_device_mesh
from repro.launch.mesh import make_production_mesh
from repro.train.loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--shape", default=None, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=("none", "full", "dots"))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--mesh", default="auto", choices=("auto", "single-pod", "multi-pod"))
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.shape:
        shape = INPUT_SHAPES[args.shape]
    else:
        shape = InputShape("cli", seq_len=args.seq, global_batch=args.batch, kind="train")

    if args.mesh == "auto":
        mesh = single_device_mesh() if len(jax.devices()) == 1 else make_production_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi-pod"))

    train(
        cfg, shape, mesh,
        steps=args.steps, peak_lr=args.lr, microbatches=args.microbatches,
        remat=args.remat, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
    )


if __name__ == "__main__":
    main()
