"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run driver
must set ``XLA_FLAGS`` before the first jax call.

Production target (TPU v5e):
  - single pod:  (16, 16)      axes ('data', 'model')   — 256 chips
  - multi-pod:   (2, 16, 16)   axes ('pod', 'data', 'model') — 512 chips

The 'pod' axis carries pure data parallelism (one gradient all-reduce per
step crosses the inter-pod links); 'data' is intra-pod data parallel +
FSDP; 'model' is tensor/expert parallel and the engine grid's column axis.
"""

from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh

from repro.core.layouts import AXIS_DATA, AXIS_MODEL, AXIS_POD

SINGLE_POD_SHAPE: Tuple[int, int] = (16, 16)
MULTI_POD_SHAPE: Tuple[int, int, int] = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = (AXIS_POD, AXIS_DATA, AXIS_MODEL) if multi_pod else (AXIS_DATA, AXIS_MODEL)
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 2)) -> Mesh:
    """Small mesh for CPU multi-device tests (requires forced host devices)."""
    axes = ((AXIS_POD, AXIS_DATA, AXIS_MODEL) if len(shape) == 3 else (AXIS_DATA, AXIS_MODEL))
    return jax.make_mesh(shape, axes)
