"""Tuned runtime environment — the allocator/flags recipe as code.

The deployment papers attribute a sizeable slice of bridge overhead to the
host runtime rather than the wire: allocator churn on multi-GB staging
buffers and logging noise on the hot path. Production JAX launchers fix this
with a small environment recipe (tcmalloc via ``LD_PRELOAD``, a large-alloc
report threshold so numpy-sized buffers don't warn, quiet TF logging, an
explicit emulated device count, 32-bit default dtypes). This module applies
that recipe reproducibly and — just as important for benchmarking — records
*which* runtime actually ran, so a regression can be attributed to
environment drift instead of code.

``LD_PRELOAD`` only takes effect at process start, so :func:`ensure_tuned`
re-execs the interpreter once with the tuned environment (guarded by a
sentinel variable); ``benchmarks/run.py --tuned`` is the caller.
"""

from __future__ import annotations

import os
import sys
from typing import Dict, Optional

#: sentinel marking "this process was re-exec'd with the tuned env"
_SENTINEL = "REPRO_TUNED"

#: usual tcmalloc install locations (SNIPPETS-style deployments)
_TCMALLOC_PATHS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
)


def find_tcmalloc() -> Optional[str]:
    """Path of an installed tcmalloc, or None (skip gracefully — CI runners
    without gperftools still run the tuned harness, minus the allocator)."""
    for path in _TCMALLOC_PATHS:
        if os.path.exists(path):
            return path
    return None


def tuned_env(
    base: Optional[Dict[str, str]] = None, device_count: int = 8
) -> Dict[str, str]:
    """The tuned environment: ``base`` (default ``os.environ``) plus the
    recipe. Existing ``XLA_FLAGS`` are merged, not clobbered; an existing
    ``LD_PRELOAD`` is left alone (the operator knows better)."""
    env = dict(base if base is not None else os.environ)
    env[_SENTINEL] = "1"
    tcmalloc = find_tcmalloc()
    if tcmalloc and "LD_PRELOAD" not in env:
        env["LD_PRELOAD"] = tcmalloc
    # no large-alloc warnings on multi-GB staging buffers
    env.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD", "60000000000")
    env.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")  # quiet the hot path
    env.setdefault("JAX_DEFAULT_DTYPE_BITS", "32")
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        flags = f"{flags} --xla_force_host_platform_device_count={device_count}".strip()
        env["XLA_FLAGS"] = flags
    return env


def is_tuned() -> bool:
    """Is this process running under the tuned environment?"""
    return os.environ.get(_SENTINEL) == "1"


def ensure_tuned(device_count: int = 8) -> None:
    """Re-exec the interpreter once with the tuned environment.

    No-op when already tuned. Must run before ``import jax`` to matter:
    ``LD_PRELOAD`` and ``XLA_FLAGS`` bind at process/backend start.
    """
    if is_tuned():
        return
    env = tuned_env(device_count=device_count)
    # ``python -m pkg.mod`` resolves against the CWD, but the re-exec sees
    # argv[0] as the resolved script path and runs in script mode — keep the
    # launch directory importable so ``import benchmarks`` still works.
    cwd = os.getcwd()
    pythonpath = env.get("PYTHONPATH", "")
    if cwd not in pythonpath.split(os.pathsep):
        env["PYTHONPATH"] = f"{cwd}{os.pathsep}{pythonpath}" if pythonpath else cwd
    os.execve(sys.executable, [sys.executable] + sys.argv, env)


def _loaded_allocator() -> str:
    """Which malloc actually got loaded (parsed from /proc/self/maps) —
    records the truth, not the intent: a bad LD_PRELOAD silently falls back
    to glibc and would otherwise masquerade as tuned."""
    try:
        with open("/proc/self/maps") as f:
            maps = f.read()
    except OSError:  # pragma: no cover - non-Linux
        return "unknown"
    if "tcmalloc" in maps:
        return "tcmalloc"
    if "jemalloc" in maps:
        return "jemalloc"
    return "glibc"


def snapshot() -> Dict[str, object]:
    """JSON-serializable record of the runtime this process actually has.

    Embedded in every benchmark suite's metrics block so regressions are
    attributable to environment drift (allocator, device count, flags).
    """
    import jax

    return {
        "tuned": is_tuned(),
        "allocator": _loaded_allocator(),
        "ld_preload": os.environ.get("LD_PRELOAD", ""),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "default_dtype_bits": os.environ.get("JAX_DEFAULT_DTYPE_BITS", ""),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "python": sys.version.split()[0],
    }
