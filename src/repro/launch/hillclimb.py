import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimb driver: evaluate sharding/precision variants of selected
(arch x shape) pairs and log hypothesis -> change -> before/after.

Each variant is one experiment in the §Perf methodology: a hypothesis with a
napkin-math prediction (recorded in VARIANTS below), implemented as a
ShardingRules / config change, re-lowered, re-analysed with the same
machinery as the baseline dry-run.

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb --pair deepseek-coder-33b:train_4k \
      --variants baseline,zero3,seq_parallel,bf16_params --out results/hc.json
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Callable, Dict, Optional


from repro.configs.base import ArchConfig, get_config
from repro.core.sharding import ShardingRules
from repro.launch.dryrun import lower_combo
from repro.launch.mesh import make_production_mesh


@dataclasses.dataclass
class Variant:
    name: str
    hypothesis: str
    prediction: str
    rules_fn: Optional[Callable] = None            # mesh -> ShardingRules
    cfg_fn: Optional[Callable] = None              # cfg -> cfg
    remat: str = "full"


def _bf16_params(cfg: ArchConfig) -> ArchConfig:
    return dataclasses.replace(cfg, param_dtype="bfloat16")


def _pad_vocab(cfg: ArchConfig) -> ArchConfig:
    return dataclasses.replace(cfg, pad_vocab_to_multiple=256)


def _pad_heads(cfg: ArchConfig) -> ArchConfig:
    """Pad query/kv head counts up to multiples of 16 so attention shards
    over the tensor axis (zero-init extra heads; exact for inference,
    near-exact for training)."""
    def up(h):
        return ((h + 15) // 16) * 16 if h else h
    return dataclasses.replace(cfg, n_heads=up(cfg.n_heads), n_kv_heads=up(cfg.n_kv_heads))


def _moe_ff_sharding(cfg: ArchConfig) -> ArchConfig:
    return dataclasses.replace(cfg, moe_shard_expert_ff=True)


def _moe_fine_groups(cfg: ArchConfig) -> ArchConfig:
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0)
    )


VARIANTS: Dict[str, Variant] = {
    "baseline": Variant(
        "baseline",
        "paper-faithful: FSDP(data) x TP(model), f32 params, full remat",
        "reference point",
    ),
    "zero3": Variant(
        "zero3",
        "activation all-reduces (≈6 x 1.9 GB f32/layer at TP16) dominate; "
        "ZeRO-3 replaces them with per-layer param all-gathers "
        "(≈3 passes x layer-params/256 x 255 ≈ 6 GB/layer bf16-equiv but in "
        "much smaller units and no f32 activation traffic)",
        "collective term down 3-6x for wide dense models",
        rules_fn=ShardingRules.zero3,
    ),
    "seq_parallel": Variant(
        "seq_parallel",
        "Megatron sequence parallelism: residuals shard over 'model' between "
        "blocks, so TP boundary all-reduces become reduce-scatter+all-gather "
        "pairs at 1/TP the tensor size",
        "collective term down ~2x vs baseline; memory term also down "
        "(sequence-sharded saved activations)",
        rules_fn=ShardingRules.seq_parallel,
    ),
    "bf16_params": Variant(
        "bf16_params",
        "parameter all-gathers and gradient reductions move f32 today; "
        "bf16 master params halve every param-carrying collective",
        "collective term down up to 2x where param traffic dominates",
        cfg_fn=_bf16_params,
    ),
    "zero3_full": Variant(
        "zero3_full",
        "zero3 with the model axis folded into batch (true 256-way DP): "
        "fixes the first attempt's 8.7x per-device compute inflation while "
        "keeping the activation-all-reduce elimination",
        "collective down ~4x vs baseline at baseline-level compute",
        rules_fn=ShardingRules.zero3_full,
    ),
    "zero3_full_bf16": Variant(
        "zero3_full_bf16",
        "zero3_full + bf16 params: param all-gathers are now the dominant "
        "collective, so halving their width should nearly halve the term",
        "collective down ~8x vs baseline",
        rules_fn=ShardingRules.zero3_full,
        cfg_fn=_bf16_params,
    ),
    "zero3_bf16": Variant(
        "zero3_bf16",
        "compose zero3 + bf16 params",
        "multiplicative composition of the two wins",
        rules_fn=ShardingRules.zero3,
        cfg_fn=_bf16_params,
    ),
    "seq_parallel_bf16": Variant(
        "seq_parallel_bf16",
        "compose seq_parallel + bf16 params",
        "collective term down 2-4x vs baseline",
        rules_fn=ShardingRules.seq_parallel,
        cfg_fn=_bf16_params,
    ),
    "pad_vocab": Variant(
        "pad_vocab",
        "odd vocab (e.g. 51866/92553) forces replicated logits; padding to a "
        "multiple of 256 lets the unembed matmul and softmax shard 16-way",
        "memory + collective terms down on logit-heavy (short-seq) models",
        cfg_fn=_pad_vocab,
    ),
    "pad_heads": Variant(
        "pad_heads",
        "head counts not divisible by 16 leave attention un-TP-sharded; "
        "padding heads to 16k multiples restores head-parallel attention",
        "attention per-device flops/bytes down ~TP-fold for 56/20-head archs",
        cfg_fn=_pad_heads,
    ),
    "no_remat": Variant(
        "no_remat",
        "full remat re-forwards every layer (+1x forward flops); with ZeRO "
        "freeing HBM, dropping remat trades memory for compute",
        "compute term down ~25% (train), memory term up",
        remat="none",
    ),
    "moe_ff_sharding": Variant(
        "moe_ff_sharding",
        "MoE decode all-gathers every D-sharded expert weight (~params bytes "
        "per step); sharding the expert FF dim instead turns the boundary "
        "into an activation reduce-scatter, tiny at 128 tokens/step",
        "arctic decode collective term down >10x",
        cfg_fn=_moe_ff_sharding,
    ),
    "moe_tight_capacity": Variant(
        "moe_tight_capacity",
        "capacity factor 1.25 pads expert buffers; cf=1.0 shrinks the "
        "all-to-all dispatch volume by 20%",
        "collective term down ~20% on MoE dispatch traffic",
        cfg_fn=_moe_fine_groups,
    ),
}


def run_pair(arch: str, shape: str, variant_names, multi_pod=False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    out = []
    base_report = None
    for vn in variant_names:
        v = VARIANTS[vn]
        cfg = get_config(arch)
        if vn.startswith("moe") and cfg.moe is None:
            continue  # variant inapplicable to this family
        if v.cfg_fn:
            cfg = v.cfg_fn(cfg)
        rules = v.rules_fn(mesh) if v.rules_fn else None

        # monkey-patch get_config used inside lower_combo for cfg overrides
        import repro.launch.dryrun as dr

        orig = dr.get_config
        dr.get_config = lambda a, **kw: cfg if a == arch else orig(a, **kw)
        try:
            t0 = time.perf_counter()
            res = lower_combo(arch, shape, mesh, rules=rules, remat=v.remat, verbose=False)
            dt = time.perf_counter() - t0
        except Exception as e:
            traceback.print_exc()
            out.append({
                "variant": vn, "hypothesis": v.hypothesis, "ok": False,
                "error": f"{type(e).__name__}: {e}",
            })
            continue
        finally:
            dr.get_config = orig

        r = res.report
        rec = {
            "variant": vn,
            "hypothesis": v.hypothesis,
            "prediction": v.prediction,
            "ok": res.ok,
            "compute_s": r["compute_seconds"],
            "memory_s": r["memory_seconds"],
            "collective_s": r["collective_seconds"],
            "dominant": r["dominant"],
            "flops_per_device": r["flops_per_device"],
            "hbm_bytes_per_device": r["hbm_bytes_per_device"],
            "collective_bytes_per_device": r["collective_bytes_per_device"],
            "temp_bytes": r.get("temp_bytes", 0),
            "eval_seconds": dt,
        }
        if vn == "baseline":
            base_report = rec
        if base_report:
            for term in ("compute_s", "memory_s", "collective_s"):
                if base_report[term]:
                    rec[f"{term}_vs_base"] = rec[term] / base_report[term]
        bottleneck = max(("compute_s", "memory_s", "collective_s"), key=lambda t: rec[t])
        rec["step_lower_bound_s"] = rec[bottleneck]
        out.append(rec)
        print(f"[{arch} x {shape}] {vn}: compute={rec['compute_s']*1e3:.0f}ms "
              f"memory={rec['memory_s']*1e3:.0f}ms collective={rec['collective_s']*1e3:.0f}ms "
              f"temp={rec['temp_bytes']/2**30:.1f}GiB ({dt:.0f}s eval)")
        sys.stdout.flush()
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", action="append", required=True,
                    help="arch:shape[:v1|v2|...], repeatable")
    ap.add_argument("--variants", default="baseline,zero3,seq_parallel,bf16_params")
    ap.add_argument("--out", default="results/hillclimb.json")
    args = ap.parse_args()

    results = {}
    for pair in args.pair:
        parts = pair.split(":")
        arch, shape = parts[0], parts[1]
        variants = parts[2].split("|") if len(parts) > 2 else args.variants.split(",")
        results[f"{arch}:{shape}"] = run_pair(arch, shape, variants)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
