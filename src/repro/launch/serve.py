"""Serving launcher CLI.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --requests 4 --new-tokens 16
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.sharding import single_device_mesh
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.serve import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--context", type=int, default=256)
    ap.add_argument("--mesh", default="auto", choices=("auto", "single-pod", "multi-pod"))
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.mesh == "auto":
        mesh = single_device_mesh() if len(jax.devices()) == 1 else make_production_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi-pod"))

    model = build_model(cfg, mesh)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, mesh, params, batch_size=args.requests, context=args.context)

    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, size=rng.integers(3, 12)).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for _ in range(args.requests)
    ]
    for i, comp in enumerate(engine.serve(reqs)):
        print(f"req{i}: {comp.tokens.tolist()[:16]} "
              f"({comp.tokens_per_second:.1f} tok/s)")


if __name__ == "__main__":
    main()
