"""ServeEngine — static-batch serving with prefill + jitted decode loop.

A deliberately production-shaped slice: requests queue up, get padded into a
fixed batch, prefill populates the caches, and a jitted per-token step
decodes until every request hits its token budget or EOS. The decode step
is the same function the dry-run lowers for ``decode_32k``/``long_500k``.

Batches can also be submitted asynchronously: :meth:`ServeEngine.submit`
enqueues a batch on the engine's :class:`~repro.core.taskqueue.TaskQueue`
(the same primitive behind the Alchemist session workers, DESIGN.md §3) and
returns an :class:`~repro.core.futures.AlFuture` of the completions — the
caller stages the next batch while the current one decodes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.core.futures import AlFuture
from repro.core.sharding import ShardingRules
from repro.core.taskqueue import TaskQueue
from repro.models.registry import build_model
from repro.serve.sampling import greedy


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # [L] int32 token ids
    max_new_tokens: int = 16
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Completion:
    tokens: np.ndarray
    prefill_seconds: float
    decode_seconds: float
    tokens_per_second: float


class ServeEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        mesh: Mesh,
        params,
        *,
        batch_size: int = 8,
        context: int = 512,
        rules: Optional[ShardingRules] = None,
        sliding_window: Optional[int] = None,
        sampler: Callable = greedy,
    ):
        self.cfg = cfg
        self.mesh = mesh
        self.model = build_model(cfg, mesh, rules, sliding_window=sliding_window)
        self.params = params
        self.batch_size = batch_size
        self.context = context
        self.sampler = sampler
        self._decode = jax.jit(self.model.decode_step)
        self._prefill = jax.jit(self.model.prefill) if hasattr(self.model, "prefill") else None
        # created eagerly: a lazy unsynchronized init could race two first
        # submits into two workers, breaking the one-batch-at-a-time invariant
        self._queue = TaskQueue(name="serve-engine")

    def _pad_batch(self, requests: Sequence[Request]) -> np.ndarray:
        if len(requests) > self.batch_size:
            raise ValueError(f"batch of {len(requests)} exceeds engine batch {self.batch_size}")
        max_len = max(len(r.prompt) for r in requests)
        toks = np.zeros((self.batch_size, max_len), np.int32)
        for i, r in enumerate(requests):
            toks[i, max_len - len(r.prompt):] = r.prompt  # left-pad
        return toks

    def serve(self, requests: Sequence[Request]) -> List[Completion]:
        """Prefill via sequential decode of the prompt (universal across
        families), then jitted single-token decode to the budget."""
        cfg = self.cfg
        toks = self._pad_batch(requests)
        b, seq = toks.shape
        budget = max(r.max_new_tokens for r in requests)

        with self.mesh:
            t0 = time.perf_counter()
            state = self.model.init_decode_state(self.batch_size, self.context)
            logits = None
            for i in range(seq):
                logits, state = self._decode(self.params, state, jnp.asarray(toks[:, i : i + 1]))
            jax.block_until_ready(logits)
            t_prefill = time.perf_counter() - t0

            t0 = time.perf_counter()
            out = np.zeros((self.batch_size, budget), np.int32)
            cur = self.sampler(logits)
            for j in range(budget):
                out[:, j] = np.asarray(cur)
                logits, state = self._decode(self.params, state, jnp.asarray(cur)[:, None])
                cur = self.sampler(logits)
            jax.block_until_ready(logits)
            t_decode = time.perf_counter() - t0

        completions = []
        for i, r in enumerate(requests):
            gen = out[i]
            if r.eos_id is not None:
                hits = np.where(gen == r.eos_id)[0]
                if hits.size:
                    gen = gen[: hits[0] + 1]
            gen = gen[: r.max_new_tokens]
            completions.append(
                Completion(
                    tokens=gen,
                    prefill_seconds=t_prefill,
                    decode_seconds=t_decode,
                    tokens_per_second=(budget * len(requests)) / max(t_decode, 1e-9),
                )
            )
        return completions

    # -- asynchronous batch submission ---------------------------------------
    def submit(self, requests: Sequence[Request]) -> AlFuture:
        """Enqueue a batch; returns a future of :meth:`serve`'s completions.

        Batches run FIFO on a single worker (one static-batch engine can only
        decode one batch at a time), but the caller returns immediately —
        request admission, tokenization, and staging of the next batch all
        overlap with the current batch's decode loop.
        """
        batch = list(requests)
        return self._queue.submit(lambda: self.serve(batch), label=f"batch[{len(batch)}]")

    def drain(self, timeout: Optional[float] = None) -> None:
        """Barrier: wait for every submitted batch to finish."""
        self._queue.barrier(timeout)

    def close(self, wait: bool = True) -> None:
        """Stop accepting batches and (optionally) drain in-flight ones."""
        self._queue.close(wait=wait)

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
