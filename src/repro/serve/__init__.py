"""serve — batched inference: prefill, decode loops, request batching.

Decode shapes from the assignment (``decode_32k``, ``long_500k``) lower
``serve_step`` (one token against a pre-filled cache), built here.
"""

from repro.serve.engine import Completion, Request, ServeEngine
from repro.serve.sampling import greedy, temperature_sample

__all__ = ["ServeEngine", "Request", "Completion", "greedy", "temperature_sample"]
