"""EngineServer + TcpTransport — the engine behind a real socket.

DESIGN.md §11/§13. The paper's deployment is two processes bridged by a
network: Spark's driver speaks to the Alchemist driver over a socket, matrix
payloads cross between worker sets, and a dropped connection must return the
client's worker group to the pool. This module is that server for the
reproduction:

- :class:`EngineServer` — a threaded TCP server wrapping one
  :class:`~repro.core.engine.AlchemistEngine`. Each accepted connection binds
  at most one session (CONNECT allocates it; HELLO with a session token
  re-binds an existing one after a drop). Requests are length-prefixed ALWF
  control frames (:mod:`repro.core.transport`) executed against an
  engine-side :class:`~repro.core.client.ClientCore` twin; replies are
  OK/ERR/ARRAY frames. A disconnect releases the bound session — its worker
  group returns to the pool — unless ``linger > 0`` grants a reconnect
  window for the token to re-bind within.
- :class:`TcpTransport` — the client half of the seam: the same five verbs
  as loopback, spoken over a localhost socket. Submission verbs return after
  the server *enqueues* (an integer ticket names the engine-side future);
  collect results are pulled with FETCH, which streams the array back in
  per-shard slabs.

**The v2 data plane (PR 9).** Wire version 2 makes the socket a streaming,
pipelined path instead of stop-and-wait:

- *Multi-in-flight RPC*: every request carries a client-minted ``__rid``;
  replies echo it. A reader thread on the client demultiplexes, so sends,
  runs, FETCHes, and barriers interleave on one socket — the server runs
  blocking verbs (FETCH result waits, BARRIER drains) on worker threads with
  a per-connection write lock serializing reply frames.
- *Shard-direct receive*: a SEND whose ARRAY frame declares shard-aligned
  chunking (``__shards``/``__srows``) decodes each chunk straight into a
  per-shard staging slab from the governor's pool and can overlap per-shard
  ``device_put`` with the remaining socket reads — no full-array reassembly
  buffer. Frames without the geometry take the classic reassembly path.
- *Streamed FETCH*: row-slab shards of the collected array are pulled off
  the device one slab at a time (next slab's ``device_get`` overlaps the
  current slab's socket write) and coalesced into vectored ``sendmsg``
  writes.
- *Version gate*: HELLO/CONNECT carry ``__version``; a mismatched client
  gets a typed ERR naming both versions, never garbage frames.

Loopback-parity deployment: the server thread lives in the engine's process
(``ensure_server``), so handles and futures the RPCs name can be resolved to
the live in-process objects (``session_object``/``take_future``) while every
control frame and payload byte genuinely crosses the socket. The bridge-byte
accounting (``SessionStats``) runs engine-side in both transports, which is
what makes the loopback and TCP counters comparable — the wire benchmark's
parity check and CI's ``REPRO_TRANSPORT=tcp`` tier-1 run both lean on this.
A fully remote client would add a client-side handle cache; the protocol
already carries everything it needs (handles cross as HandleRefs, futures as
tickets, arrays as framed bytes).
"""

from __future__ import annotations

import itertools
import json
import socket
import struct
import threading
import uuid
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import transport as wire
from repro.core.errors import AlchemistError, ParameterError, SessionError, TaskError
from repro.core.futures import AlFuture
from repro.core.layouts import by_name as layout_by_name
from repro.core.params import HandleRef
from repro.core.transport import Transport

_SERVERS: Dict[int, "EngineServer"] = {}
_SERVERS_LOCK = threading.Lock()


def ensure_server(engine, **kwargs) -> "EngineServer":
    """The engine's singleton wire server, started on first use."""
    with _SERVERS_LOCK:
        srv = _SERVERS.get(id(engine))
        if srv is None or srv.closed:
            srv = EngineServer(engine, **kwargs)
            _SERVERS[id(engine)] = srv
        return srv


def server_for(engine) -> Optional["EngineServer"]:
    """The engine's live wire server, if one was ever started (stats hook)."""
    with _SERVERS_LOCK:
        srv = _SERVERS.get(id(engine))
        return None if srv is None or srv.closed else srv


class _Bound:
    """One session's server-side state: the engine core twin, the ticket
    table naming its in-flight futures, and the reconnect bookkeeping."""

    def __init__(self, token: str, session, core):
        self.token = token
        self.session = session
        self.core = core
        self.futures: Dict[int, AlFuture] = {}
        self._tickets = itertools.count(1)
        self.lock = threading.Lock()
        self.released = False
        self.linger_timer: Optional[threading.Timer] = None

    def ticket(self, fut: AlFuture) -> int:
        with self.lock:
            t = next(self._tickets)
            self.futures[t] = fut
        return t

    def future(self, t: int) -> AlFuture:
        with self.lock:
            try:
                return self.futures[t]
            except KeyError:
                raise SessionError(f"unknown ticket {t} for session {self.session.id}") from None


class _ConnState:
    """Per-connection v2 state: the reply write lock (worker threads and the
    connection loop interleave OK/ERR/ARRAY frames on one socket) and the
    in-flight request depth."""

    def __init__(self, sock: Optional[socket.socket] = None):
        self.wlock = threading.RLock()
        self.inflight = 0
        self.max_inflight = 0
        self._lock = threading.Lock()
        self.sock = sock

    def shutdown(self) -> None:
        """Tear the socket down under the peer: blocked ``recv``/``send``
        calls in the connection loop and worker threads return immediately
        instead of serving a stopped engine."""
        if self.sock is None:
            return
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass

    def enter(self) -> int:
        with self._lock:
            self.inflight += 1
            self.max_inflight = max(self.max_inflight, self.inflight)
            return self.inflight

    def exit(self) -> None:
        with self._lock:
            self.inflight -= 1


class EngineServer:
    """Threaded TCP server wrapping an AlchemistEngine (DESIGN.md §11)."""

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 0, linger: float = 0.0):
        self.engine = engine
        self.linger = linger
        self.closed = False
        self._sock = socket.create_server((host, port))
        self.address: Tuple[str, int] = self._sock.getsockname()[:2]
        self._lock = threading.Lock()
        self._stop_lock = threading.Lock()
        self._stopped = False
        self._bound: Dict[str, _Bound] = {}
        self._conns: List[_ConnState] = []
        self.stats = {
            "connections": 0,
            "disconnect_releases": 0,  # sessions torn down by a dropped socket
            "reconnects": 0,  # HELLO re-binds within the linger window
            "frames": 0,
            "bytes_in": 0,
            "bytes_out": 0,
            # -- v2 data plane (DESIGN.md §13) --------------------------------
            "vectored_writes": 0,  # sendmsg syscall batches on replies
            "shard_direct_receives": 0,  # SENDs decoded straight into shard slabs
            "reassembly_receives": 0,  # SENDs through the one-buffer fallback
            "streamed_fetches": 0,  # FETCHes streamed slab-by-slab off device
            "gathered_fetches": 0,  # FETCHes through the full-gather fallback
            "overlap_ns": 0,  # Σ device_put time inside the socket window
            "put_ns": 0,  # Σ device_put time on shard-direct receives
            "max_inflight": 0,  # deepest per-connection request pipeline seen
            "version_rejects": 0,  # HELLO/CONNECTs refused on __version
        }
        self._accept = threading.Thread(
            target=self._accept_loop, name=f"wire-{self.address[1]}", daemon=True
        )
        self._accept.start()

    # -- in-process parity lookups (see module docstring) --------------------
    def session_object(self, token: str):
        return self._require(token).session

    def take_future(self, token: str, ticket: int) -> AlFuture:
        return self._require(token).future(ticket)

    def register_future(self, token: str, fut: AlFuture) -> int:
        """Admit an engine-side future the server did not itself mint into
        the session's ticket table (derived futures: `.then` projections the
        planner builds over RUN outputs). In-process parity only — a fully
        remote client would await the projection and reference the handle."""
        return self._require(token).ticket(fut)

    def _require(self, token: str) -> _Bound:
        with self._lock:
            try:
                return self._bound[token]
            except KeyError:
                raise SessionError(f"unknown or expired session token {token!r}") from None

    def has_session(self, token: str) -> bool:
        with self._lock:
            return token in self._bound

    def inflight_depth(self) -> int:
        """Requests currently executing across all live connections."""
        with self._lock:
            return sum(c.inflight for c in self._conns)

    # -- lifecycle -----------------------------------------------------------
    def stop(self) -> None:
        """Stop accepting, release every still-bound session, and shut down
        live per-connection sockets so mid-FETCH worker threads unblock.

        Safe to call from a supervisor thread at any time, including while
        connection loops and data-plane workers are active; a second (or
        concurrent) stop is a no-op. The stop flag is claimed under its own
        lock so a re-entrant call never deadlocks against ``_release`` or a
        connection teardown holding ``self._lock``.
        """
        with self._stop_lock:
            if self._stopped:
                return
            self._stopped = True
        self.closed = True
        # shutdown() before close(): a thread parked in accept() holds the
        # listening socket's open file description, so close() alone leaves
        # the port accepting connections until that thread wakes. shutdown
        # forces the blocked accept to return so the listener really dies.
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        with self._lock:
            bound = list(self._bound.values())
            self._bound.clear()
            conns = list(self._conns)
        for b in bound:
            self._release(b, why="server stop")
        for c in conns:
            c.shutdown()

    def close(self) -> None:
        """Alias for :meth:`stop` (historical name)."""
        self.stop()

    def _release(self, b: _Bound, why: str) -> None:
        with self._lock:
            if b.released:
                return
            b.released = True
            self._bound.pop(b.token, None)
            if b.linger_timer is not None:
                b.linger_timer.cancel()
        # engine.release drains the session queue and returns the worker
        # group to the pool in canonical order, waking queued connects.
        self.engine.release(b.session)

    # -- server loop ---------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self.closed:
            try:
                conn, _addr = self._sock.accept()
            except OSError:
                return  # listener closed
            self.stats["connections"] += 1
            threading.Thread(
                target=self._serve_connection,
                args=(conn,),
                daemon=True,
                name=f"wire-conn-{self.stats['connections']}",
            ).start()

    def _serve_connection(self, conn: socket.socket) -> None:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        cstate = _ConnState(conn)
        with self._lock:
            self._conns.append(cstate)
        bound: Optional[_Bound] = None
        explicit_close = False
        try:
            while True:
                try:
                    ftype, req, nread = wire.recv_frame(conn)
                except ConnectionError:
                    break  # peer vanished: disconnect semantics below
                self.stats["frames"] += 1
                self.stats["bytes_in"] += nread
                rid = req.pop("__rid", None)
                try:
                    bound, closed = self._dispatch(conn, cstate, ftype, req, bound, rid)
                    if closed:
                        explicit_close = True
                        break
                except AlchemistError as exc:
                    self._reply(conn, cstate, wire.T_ERR, wire.error_payload(exc), rid)
                except Exception as exc:  # noqa: BLE001 — map, never crash the loop
                    self._reply(conn, cstate, wire.T_ERR, wire.error_payload(exc), rid)
        except (ConnectionError, OSError):
            pass  # reply write failed: same as a disconnect
        finally:
            try:
                conn.close()
            except OSError:
                pass
            with self._lock:
                self.stats["max_inflight"] = max(
                    self.stats["max_inflight"], cstate.max_inflight
                )
                if cstate in self._conns:
                    self._conns.remove(cstate)
            if bound is not None and not explicit_close and not bound.released:
                if self.linger > 0:
                    # Reconnect window: keep the session bound; release only
                    # if no HELLO re-binds the token in time.
                    self._schedule_linger(bound)
                else:
                    self.stats["disconnect_releases"] += 1
                    self._release(bound, why="disconnect")

    def _schedule_linger(self, b: _Bound) -> None:
        def expire() -> None:
            with self._lock:
                if b.released or b.token not in self._bound:
                    return
            self.stats["disconnect_releases"] += 1
            self._release(b, why="linger expired")

        t = threading.Timer(self.linger, expire)
        t.daemon = True
        b.linger_timer = t
        t.start()

    def _reply(
        self,
        conn: socket.socket,
        cstate: _ConnState,
        ftype: int,
        payload: Dict[str, Any],
        rid: Optional[int],
    ) -> None:
        if rid is not None:
            payload = {**payload, "__rid": int(rid)}
        with cstate.wlock:
            n = wire.send_frame(conn, ftype, payload)
        self.stats["bytes_out"] += n

    def _spawn(self, cstate: _ConnState, fn, label: str) -> None:
        """Run a blocking verb off the connection loop so later requests on
        the same socket proceed (multi-in-flight). The per-connection write
        lock keeps its eventual reply frame atomic."""
        cstate.enter()
        self.stats["max_inflight"] = max(self.stats["max_inflight"], cstate.max_inflight)

        def run() -> None:
            try:
                fn()
            finally:
                cstate.exit()

        threading.Thread(target=run, daemon=True, name=label).start()

    # -- verb dispatch -------------------------------------------------------
    def _check_version(self, req: Dict[str, Any]) -> None:
        v = int(req.get("__version") or 1)
        if v != wire.WIRE_VERSION:
            self.stats["version_rejects"] += 1
            raise SessionError(
                f"wire protocol version mismatch: client speaks v{v}, "
                f"server speaks v{wire.WIRE_VERSION} — upgrade the client "
                "(frame formats are incompatible across versions)"
            )

    def _dispatch(
        self,
        conn: socket.socket,
        cstate: _ConnState,
        ftype: int,
        req: Dict[str, Any],
        bound: Optional[_Bound],
        rid: Optional[int],
    ) -> Tuple[Optional[_Bound], bool]:
        if ftype == wire.T_HELLO:
            self._check_version(req)
            token = req.get("__token")
            if token:
                bound = self._require(str(token))
                if bound.linger_timer is not None:
                    bound.linger_timer.cancel()
                    bound.linger_timer = None
                self.stats["reconnects"] += 1
                self._reply(
                    conn, cstate, wire.T_OK,
                    {"__sid": bound.session.id, "__version": wire.WIRE_VERSION}, rid,
                )
            else:
                self._reply(conn, cstate, wire.T_OK, {"__version": wire.WIRE_VERSION}, rid)
            return bound, False

        if ftype == wire.T_CONNECT:
            self._check_version(req)
            if bound is not None:
                raise SessionError("connection already has a bound session")
            bound = self._connect(req)
            self._reply(
                conn, cstate, wire.T_OK,
                {"__token": bound.token, "__sid": bound.session.id}, rid,
            )
            return bound, False

        if ftype == wire.T_HEALTH:
            # Control-plane scrape (DESIGN.md §14): answered inline on the
            # connection loop — no session binding, no worker-thread spawn —
            # so supervisor heartbeats never queue behind mid-FETCH
            # data-plane threads. The merged stats snapshot rides as one
            # JSON string because the ALPK codec is scalars-and-flat-lists
            # by design; `__seq` is duplicated as a scalar so a scraper can
            # reject stale or reordered replies without parsing the blob.
            snap = self.engine.stats()
            self._reply(
                conn, cstate, wire.T_OK,
                {
                    "__stats_json": json.dumps(snap),
                    "__seq": int(snap["engine"]["snapshot_seq"]),
                    "__uptime_s": float(snap["engine"]["uptime_s"]),
                },
                rid,
            )
            return bound, False

        if bound is None:
            raise SessionError(
                f"frame {wire.FRAME_NAMES.get(ftype, ftype)} before CONNECT/HELLO bound a session"
            )
        core = bound.core

        if ftype == wire.T_SEND:
            # The array body follows on the socket: it must be read on this
            # thread (frames are sequential), shard-direct when the frame
            # declares a geometry this session's layout agrees with.
            arr, nread = self._recv_send_payload(conn, bound)
            self.stats["bytes_in"] += nread
            payload = None
            if bool(req.get("__has_payload")):
                # The offload planner wants a host snapshot for the content
                # store; staged payloads materialize here — the one place a
                # shard-direct receive pays a full host copy (documented:
                # plain sends, the hot path, never do).
                payload = np.asarray(arr)
            fut = core._local_submit_send(
                arr,
                name=str(req.get("__name") or ""),
                block=bool(req.get("__block")),
                key=None,
                payload=payload,
            )
            self._reply(conn, cstate, wire.T_OK, {"__ticket": bound.ticket(fut)}, rid)

        elif ftype == wire.T_RUN:
            dec = wire.decode_run_request(
                req, future_of=bound.future, handle_of=self._lenient_handle(bound)
            )
            fut = core._local_submit_run(
                dec["library"],
                dec["routine"],
                dec["args"],
                dec["params"],
                block=dec["block"],
                out_shapes=dec["out_shapes"],
                out_dtype=dec["out_dtype"],
            )
            self._reply(conn, cstate, wire.T_OK, {"__ticket": bound.ticket(fut)}, rid)

        elif ftype == wire.T_COLLECT:
            target = self._target(bound, req)
            fut = core._local_submit_collect(target)
            self._reply(conn, cstate, wire.T_OK, {"__ticket": bound.ticket(fut)}, rid)

        elif ftype == wire.T_FETCH:
            fut = bound.future(int(req["__ticket"]))
            timeout = req.get("__timeout")
            self._spawn(
                cstate,
                lambda: self._do_fetch(
                    conn, cstate, bound, fut,
                    None if timeout is None else float(timeout), rid,
                ),
                label="wire-fetch",
            )

        elif ftype == wire.T_FREE:
            target = self._target(bound, req)
            fut = core._local_free_async(target)
            self._reply(conn, cstate, wire.T_OK, {"__ticket": bound.ticket(fut)}, rid)

        elif ftype == wire.T_BARRIER:
            timeout = req.get("__timeout")
            self._spawn(
                cstate,
                lambda: self._do_barrier(
                    conn, cstate, bound,
                    None if timeout is None else float(timeout), rid,
                ),
                label="wire-barrier",
            )

        elif ftype == wire.T_REGISTER:
            core._local_register_library(str(req["__name"]), str(req["__spec"]))
            self._reply(conn, cstate, wire.T_OK, {}, rid)

        elif ftype == wire.T_CLOSE:
            self._release(bound, why="client close")
            self._reply(conn, cstate, wire.T_OK, {}, rid)
            return bound, True

        else:
            raise SessionError(f"unknown wire frame type 0x{ftype:02x}")
        return bound, False

    # -- SEND: shard-direct receive (DESIGN.md §13) ---------------------------
    def _recv_send_payload(self, conn: socket.socket, bound: _Bound):
        """The ARRAY body following a SEND → (array-or-StagedShards, bytes).

        Frames declaring shard-aligned chunking decode straight into staging
        slabs from the governor's pool, with eager per-shard ``device_put``
        on the transfer ring when no HBM budget gates admission; anything
        else (v2 frames without geometry, geometry the session's layout no
        longer matches) reassembles into the one buffer that becomes the
        payload array. Mid-stream failure returns every unclaimed slab to
        the pool and re-raises — no handle exists yet, so nothing is
        half-admitted."""
        from repro.core.relayout import shard_geometry

        ftype, meta, n0 = wire.recv_frame(conn)
        if ftype != wire.T_ARRAY:
            raise ParameterError(
                f"SEND must be followed by an ARRAY frame, got "
                f"{wire.FRAME_NAMES.get(ftype, ftype)}"
            )
        if meta.get("__shards") and not bound.core.engine_layout.cyclic:
            sess = bound.session
            shape = (int(meta["__rows"]), int(meta["__cols"]))
            geom = shard_geometry(
                shape, meta["__dtype"], bound.core.client_layout, sess.mesh
            )
            if (
                geom is not None
                and geom.n_shards == int(meta["__shards"])
                and geom.shard_rows == int(meta["__srows"])
            ):
                mg = sess.memgov
                recv = wire.ShardStreamReceiver(
                    meta, geom,
                    pool=mg.staging, ring=mg.transfer_ring(), eager=mg.unbudgeted(),
                )
                try:
                    nbody = recv.recv_body(conn)
                except BaseException:
                    recv.abort()  # idempotent: pool release dedups by identity
                    raise
                staged = recv.staged
                staged.on_assembled = self._record_overlap
                self.stats["shard_direct_receives"] += 1
                return staged, n0 + nbody
        arr, nbody = wire.recv_array_body(conn, meta)
        self.stats["reassembly_receives"] += 1
        return arr, n0 + nbody

    def _record_overlap(self, staged) -> None:
        ratio = staged.overlap_ratio()
        if ratio is None:
            return
        put = sum(e - s for s, e in staged.put_windows)
        self.stats["put_ns"] += int(put * 1e9)
        self.stats["overlap_ns"] += int(ratio * put * 1e9)

    # -- FETCH: streamed send (DESIGN.md §13) ---------------------------------
    def _do_fetch(
        self,
        conn: socket.socket,
        cstate: _ConnState,
        bound: _Bound,
        fut: AlFuture,
        timeout: Optional[float],
        rid: Optional[int],
    ) -> None:
        try:
            val = fut.result(timeout)
        except BaseException as exc:  # noqa: BLE001 — crosses as an ERR frame
            try:
                self._reply(conn, cstate, wire.T_ERR, wire.error_payload(exc), rid)
            except (ConnectionError, OSError):
                pass
            return
        try:
            self._send_fetch_array(conn, cstate, bound, val, rid)
        except (ConnectionError, OSError):
            pass  # peer vanished; the connection loop owns teardown

    def _do_barrier(
        self,
        conn: socket.socket,
        cstate: _ConnState,
        bound: _Bound,
        timeout: Optional[float],
        rid: Optional[int],
    ) -> None:
        try:
            bound.session.drain(timeout)
        except BaseException as exc:  # noqa: BLE001
            try:
                self._reply(conn, cstate, wire.T_ERR, wire.error_payload(exc), rid)
            except (ConnectionError, OSError):
                pass
            return
        try:
            self._reply(conn, cstate, wire.T_OK, {}, rid)
        except (ConnectionError, OSError):
            pass

    def _send_fetch_array(
        self,
        conn: socket.socket,
        cstate: _ConnState,
        bound: _Bound,
        val: Any,
        rid: Optional[int],
    ) -> None:
        slabs = _row_slabs(val)
        if slabs is None:
            out = np.asarray(val)
            self.stats["gathered_fetches"] += 1
            header, chunks, framed = wire.encode_array(out)
            if rid is not None:
                # Re-pack with the rid folded into the ARRAY meta so the
                # client reader can correlate the reply.
                meta = wire.array_header(out)
                meta["__rid"] = int(rid)
                header = wire.pack_frame(wire.T_ARRAY, meta)
                framed = len(header) + sum(8 + len(c) for c in chunks)
            bufs: List[Any] = [header]
            for c in chunks:
                bufs.append(struct.pack("<Q", len(c)))
                bufs.append(c)
            with cstate.wlock:
                wire.sendmsg_all(conn, bufs, self.stats)
            self.stats["bytes_out"] += framed
            return

        # Streamed path: slab i+1's device_get overlaps slab i's socket
        # write. The meta is computable from shard indices alone — no gather.
        self.stats["streamed_fetches"] += 1
        rows, cols = int(val.shape[0]), int(val.shape[1])
        itemsize = np.dtype(val.dtype).itemsize
        slab_bytes = [(stop - start) * cols * itemsize for (start, stop, _sh) in slabs]
        meta = {
            "__rows": rows,
            "__cols": cols,
            "__dtype": np.dtype(val.dtype).name,
            "__nbytes": rows * cols * itemsize,
            "__pad_r": 0,
            "__pad_c": 0,
            "__chunks": sum(-(-b // wire.CHUNK_BYTES) for b in slab_bytes if b),
        }
        if rid is not None:
            meta["__rid"] = int(rid)
        header = wire.pack_frame(wire.T_ARRAY, meta)
        ring = bound.session.memgov.transfer_ring()

        def launch(i: int):
            ev = threading.Event()
            box: Dict[str, np.ndarray] = {}

            def job() -> None:
                try:
                    box["v"] = np.asarray(slabs[i][2].data)
                finally:
                    ev.set()

            if not ring.try_submit(job):
                job()
            return ev, box

        sent = len(header)
        pending = launch(0)
        with cstate.wlock:
            conn.sendall(header)
            for i in range(len(slabs)):
                ev, box = pending
                ev.wait()
                cur = box["v"]
                if i + 1 < len(slabs):
                    pending = launch(i + 1)  # overlap next device_get
                data = memoryview(np.ascontiguousarray(cur)).cast("B")
                bufs = []
                for off in range(0, data.nbytes, wire.CHUNK_BYTES):
                    c = data[off : off + wire.CHUNK_BYTES]
                    bufs.append(struct.pack("<Q", c.nbytes))
                    bufs.append(c)
                if bufs:
                    sent += wire.sendmsg_all(conn, bufs, self.stats)
        self.stats["bytes_out"] += sent

    def _connect(self, req: Dict[str, Any]) -> _Bound:
        from repro.core.client import ClientCore

        n_keys = int(req.get("__n_keys") or 0)
        datasets = [
            (
                tuple(req[f"__k{i}_shape"]),
                str(req[f"__k{i}_dtype"]),
                str(req[f"__k{i}_sha"]),
            )
            for i in range(n_keys)
        ]
        from repro.core.scheduler import PlacementRequest

        grid = req.get("__grid")
        workers = req.get("__workers")
        if "__deadline" in req or "__priority" in req:
            deadline = req.get("__deadline")
            placement = PlacementRequest(
                workers=None if workers is None else int(workers),
                grid=None if grid is None else tuple(int(d) for d in grid),
                priority=int(req.get("__priority") or 0),
                affinity=tuple(datasets),
                deadline=None if deadline is None else float(deadline),
                allow_shared=bool(req.get("__allow_shared", True)),
            )
        else:
            # v1 client (pre-scheduler wire): __queue/__timeout semantics.
            timeout = req.get("__timeout")
            placement = PlacementRequest(
                workers=None if workers is None else int(workers),
                grid=None if grid is None else tuple(int(d) for d in grid),
                affinity=tuple(datasets),
                deadline=(
                    (None if timeout is None else float(timeout))
                    if bool(req.get("__queue"))
                    else 0.0
                ),
            )
        session = self.engine.connect(
            name=str(req.get("__name") or "app"),
            hbm_budget=req.get("__hbm_budget"),
            placement=placement,
        )
        core = ClientCore._over_session(
            self.engine,
            session,
            layout_by_name(str(req.get("__clayout") or "row")),
            layout_by_name(str(req.get("__elayout") or "grid")),
        )
        b = _Bound(uuid.uuid4().hex, session, core)
        with self._lock:
            self._bound[b.token] = b
        return b

    def _target(self, bound: _Bound, req: Dict[str, Any]):
        """COLLECT/FREE target: a ticket naming an in-flight future, or a
        HandleRef resolved against the session table — leniently, so an
        unknown/foreign handle fails inside the task (the classic surface),
        not at the RPC."""
        if "__ticket" in req:
            return bound.future(int(req["__ticket"]))
        return self._lenient_handle(bound)(req["__h"])

    def _lenient_handle(self, bound: _Bound):
        def resolve(ref: HandleRef):
            live = bound.session.handles.get(ref.id)
            return live if live is not None else ref
        return resolve


def _row_slabs(val: Any) -> Optional[List[Tuple[int, int, Any]]]:
    """Contiguous full-width row slabs covering ``val``, in row order, or
    None when the array cannot stream (host array, non-2D, column-sharded,
    strided, empty). Replicated shards dedup by start row — one copy crosses
    the wire."""
    import jax

    if not isinstance(val, jax.Array) or val.ndim != 2 or val.shape[0] == 0:
        return None
    try:
        shards = list(val.addressable_shards)
    except Exception:  # pragma: no cover - exotic arrays
        return None
    rows, cols = int(val.shape[0]), int(val.shape[1])
    by_start: Dict[int, Tuple[int, Any]] = {}
    for sh in shards:
        idx = sh.index
        r = idx[0] if len(idx) >= 1 else slice(None)
        c = idx[1] if len(idx) >= 2 else slice(None)
        if not isinstance(r, slice) or not isinstance(c, slice):
            return None
        if c.start not in (None, 0) or c.stop not in (None, cols) or c.step not in (None, 1):
            return None  # column-sharded: no contiguous row slabs
        if r.step not in (None, 1):
            return None
        start = r.start or 0
        stop = rows if r.stop is None else int(r.stop)
        if start not in by_start:  # replicas: first copy wins
            by_start[start] = (stop, sh)
    out: List[Tuple[int, int, Any]] = []
    pos = 0
    while pos < rows:
        got = by_start.get(pos)
        if got is None:
            return None  # gap: the shards do not partition the rows
        stop, sh = got
        if stop <= pos:
            return None
        out.append((pos, stop, sh))
        pos = stop
    return out if pos == rows else None


class _TcpCollectFuture(AlFuture):
    """Client half of a wire collect: COLLECT enqueued engine-side (ticket),
    bytes pulled through FETCH on first ``result()``. ``done()``/callbacks
    observe the engine-side future (in-process parity, see module doc);
    the payload itself always crosses the socket exactly once."""

    def __init__(self, transport: "TcpTransport", ticket: int, engine_fut: AlFuture):
        super().__init__(label=f"collect:tcp:{ticket}")
        self._transport = transport
        self._ticket = ticket
        self._engine_fut = engine_fut
        self._fetch_lock = threading.Lock()

    def done(self) -> bool:
        return self._event.is_set() or self._engine_fut.done()

    def add_done_callback(self, fn) -> None:
        if self._event.is_set():
            fn(self)
            return
        self._engine_fut.add_done_callback(lambda _parent: fn(self))

    def _ensure_fetched(self, timeout: Optional[float]) -> None:
        """Pull the payload once. Task failures memoize into this future;
        a wait timeout (server-side ``result(timeout)`` expiring) raises
        without memoizing, so a later call can still succeed."""
        with self._fetch_lock:
            if self._event.is_set():
                return
            try:
                arr = self._transport._fetch(self._ticket, timeout)
            except TaskError as exc:
                if "not resolved within" in str(exc):
                    raise  # transient wait timeout crossing as TaskError
                self._set_exception(exc)
            except BaseException as exc:  # noqa: BLE001 — future API contract
                self._set_exception(exc)
            else:
                self._set_result(arr)

    def exception(self, timeout: Optional[float] = None) -> Optional[BaseException]:
        self._ensure_fetched(timeout)
        return super().exception(timeout)

    def result(self, timeout: Optional[float] = None) -> Any:
        self._ensure_fetched(timeout)
        return super().result(timeout)


class _WireSocket(socket.socket):
    """Client socket whose ``close()`` severs the TCP connection *now*.

    The v2 transport keeps a reader thread blocked in ``recv`` on this
    socket. A plain ``close()`` only drops the fd — the kernel keeps the
    connection (and never sends FIN) while the blocked syscall holds the
    file description, so the server would never observe the disconnect.
    ``shutdown`` acts on the connection itself: FIN goes out immediately and
    the blocked reader wakes with EOF. This is also what keeps the test
    idiom ``transport._sock.close()`` meaning "client process died"."""

    def close(self):  # noqa: D102 — see class doc
        try:
            self.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass  # never connected / already reset
        super().close()


class _Waiter:
    """One in-flight RPC's reply slot, filled by the reader thread."""

    __slots__ = ("event", "kind", "reply", "array", "error")

    def __init__(self):
        self.event = threading.Event()
        self.kind = ""
        self.reply: Dict[str, Any] = {}
        self.array: Optional[np.ndarray] = None
        self.error: Optional[BaseException] = None

    def deliver(self, kind: str, reply: Dict[str, Any], array) -> None:
        self.kind, self.reply, self.array = kind, reply, array
        self.event.set()

    def fail(self, exc: BaseException) -> None:
        self.error = exc
        self.event.set()

    def wait(self):
        self.event.wait()
        if self.error is not None:
            raise self.error
        return self.kind, self.reply, self.array


class TcpTransport(Transport):
    """Client-side wire: the five verbs over one localhost TCP connection.

    One connection per client core (sessions stay independently socketed, so
    cross-session overlap survives the wire). Since v2 the connection is
    **multi-in-flight**: every request carries a ``__rid``, a reader thread
    demultiplexes correlated replies, and concurrent callers pipeline on the
    socket instead of serializing behind one lock-held round trip. On a
    broken socket, a transport holding a session token transparently
    reconnects (HELLO + token) exactly once per failure epoch and retries
    the RPC — the server side of the story is ``EngineServer`` linger.
    """

    name = "tcp"

    def __init__(self, server: Optional[EngineServer] = None):
        self._server = server
        self._sock: Optional[socket.socket] = None
        self._wlock = threading.RLock()  # socket writes + waiter registration
        self._conn_lock = threading.RLock()  # reconnects are single-flight
        self._reconnect_epoch = 0
        self._waiters: Dict[int, _Waiter] = {}
        self._rids = itertools.count(1)
        self.token: Optional[str] = None
        self.bytes_sent = 0
        self.bytes_received = 0
        self.frames = 0
        self.counters: Dict[str, int] = {"vectored_writes": 0}
        self._max_inflight = 0

    # -- connection management ----------------------------------------------
    @property
    def server(self) -> EngineServer:
        if self._server is None:
            raise SessionError("TcpTransport has no server; open_session first")
        return self._server

    def _dial(self) -> None:
        sock = _WireSocket(socket.AF_INET, socket.SOCK_STREAM)
        try:
            sock.connect(self.server.address)
        except BaseException:
            sock.close()
            raise
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._sock = sock
        threading.Thread(
            target=self._read_loop,
            args=(self._sock,),
            daemon=True,
            name="wire-client-reader",
        ).start()

    def _read_loop(self, sock: socket.socket) -> None:
        """Reply demultiplexer: one per socket epoch. ARRAY bodies are read
        inline (frames are sequential on the wire); socket death fails every
        waiter with ConnectionError so their RPCs can retry on a fresh
        socket."""
        while True:
            try:
                rtype, reply, nread = wire.recv_frame(sock)
                self.bytes_received += nread
                array = None
                if rtype == wire.T_ARRAY:
                    array, nbody = wire.recv_array_body(sock, reply)
                    self.bytes_received += nbody
            except BaseException as exc:  # noqa: BLE001 — fail all, exit
                err = exc if isinstance(exc, (ConnectionError, OSError)) else (
                    ConnectionError(f"wire reader failed: {exc}")
                )
                with self._wlock:
                    waiters = list(self._waiters.values())
                    self._waiters.clear()
                for w in waiters:
                    w.fail(err)
                return
            rid = reply.get("__rid")
            with self._wlock:
                w = self._waiters.pop(int(rid), None) if rid is not None else None
            if w is None:
                continue  # stale reply from before a reconnect
            kind = {wire.T_ERR: "err", wire.T_ARRAY: "array"}.get(rtype, "ok")
            w.deliver(kind, reply, array)

    def open_session(self, core, kwargs):
        if self._server is None:
            self._server = ensure_server(core.engine)
        self._dial()
        try:
            self._rpc_once(
                wire.T_HELLO, {"__token": None, "__version": wire.WIRE_VERSION}
            )
            reply = self._rpc_once(wire.T_CONNECT, self._connect_payload(core, kwargs))
        except BaseException:
            self._close_sock()
            raise
        self.token = str(reply["__token"])
        return self.server.session_object(self.token)

    def _connect_payload(self, core, kwargs) -> Dict[str, Any]:
        from repro.core.engine import _dataset_keys
        from repro.core.scheduler import PlacementRequest

        # CONNECT carries the declarative PlacementRequest (DESIGN.md §12).
        # Affinity payloads are hashed to content keys client-side — same
        # gate the engine applies (content_key reads every byte) — so the
        # wire never ships dataset bytes at connect time.
        request: PlacementRequest = kwargs.get("placement") or PlacementRequest(deadline=0.0)
        affinity = request.affinity or ()
        keys = _dataset_keys(affinity) if affinity and core.engine.residents.enabled else []
        payload: Dict[str, Any] = {
            "__version": wire.WIRE_VERSION,
            "__name": kwargs.get("name") or "app",
            "__workers": request.workers,
            "__grid": None if request.grid is None else [int(d) for d in request.grid],
            "__hbm_budget": kwargs.get("hbm_budget"),
            "__priority": int(request.priority),
            "__deadline": None if request.deadline is None else float(request.deadline),
            "__allow_shared": bool(request.allow_shared),
            "__clayout": core.client_layout.name,
            "__elayout": core.engine_layout.name,
            "__n_keys": len(keys),
        }
        for i, (shape, dtype, sha) in enumerate(keys):
            payload[f"__k{i}_shape"] = [int(d) for d in shape]
            payload[f"__k{i}_dtype"] = str(dtype)
            payload[f"__k{i}_sha"] = str(sha)
        return payload

    def reconnect(self) -> None:
        """Re-dial and re-bind the session token (requires server linger or
        a still-open server binding)."""
        if self.token is None:
            raise SessionError("no session token to reconnect with")
        self._close_sock()
        self._dial()
        self._rpc_once(
            wire.T_HELLO, {"__token": self.token, "__version": wire.WIRE_VERSION}
        )

    def _recover(self, epoch: int) -> None:
        """Single-flight reconnect: the first RPC to observe the failure
        epoch re-dials; concurrent failures wait on the lock, see the bumped
        epoch, and go straight to their retry on the fresh socket."""
        with self._conn_lock:
            if self._reconnect_epoch != epoch:
                return  # another thread already reconnected
            if self.token is None or not self.server.has_session(self.token):
                raise SessionError(
                    "wire connection lost and session no longer bound "
                    "(server released it on disconnect)"
                ) from None
            self.reconnect()
            self._reconnect_epoch = epoch + 1

    def _close_sock(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    # -- RPC core ------------------------------------------------------------
    def _rpc(
        self,
        ftype: int,
        payload: Dict[str, Any],
        array: Optional[np.ndarray] = None,
        expect_array: bool = False,
        geom=None,
    ):
        epoch = self._reconnect_epoch
        try:
            return self._rpc_once(ftype, payload, array, expect_array, geom)
        except (ConnectionError, OSError):
            # Broken pipe / reset / EOF mid-RPC. With a token and a server
            # that still knows it (linger window, or the drop hit us before
            # the server noticed), re-bind and retry once.
            self._recover(epoch)
            return self._rpc_once(ftype, payload, array, expect_array, geom)

    def _rpc_once(self, ftype, payload, array=None, expect_array=False, geom=None):
        rid = next(self._rids)
        waiter = _Waiter()
        with self._wlock:
            sock = self._sock
            if sock is None:
                raise ConnectionError("transport socket is closed")
            self._waiters[rid] = waiter
            self._max_inflight = max(self._max_inflight, len(self._waiters))
            try:
                self.frames += 1
                self.bytes_sent += wire.send_frame(
                    sock, ftype, {**payload, "__rid": rid}
                )
                if array is not None:
                    self.bytes_sent += wire.send_array(
                        sock, array, geom=geom, counters=self.counters
                    )
            except BaseException:
                self._waiters.pop(rid, None)
                raise
        kind, reply, arr = waiter.wait()  # ConnectionError here → _rpc retries
        if kind == "err":
            raise wire.exception_from_payload(reply)
        if kind == "array":
            if not expect_array:
                raise SessionError("unexpected ARRAY reply")
            return arr
        if expect_array:
            raise SessionError(
                f"expected ARRAY reply, got {wire.FRAME_NAMES.get(ftype, ftype)}"
            )
        return reply

    def _fetch(self, ticket: int, timeout: Optional[float]):
        return self._rpc(
            wire.T_FETCH,
            {"__ticket": ticket, "__timeout": timeout},
            expect_array=True,
        )

    def _take(self, reply: Dict[str, Any]) -> AlFuture:
        ticket = int(reply["__ticket"])
        fut = self.server.take_future(self.token, ticket)
        fut._wire_ticket = ticket
        return fut

    @staticmethod
    def _wire_ref(obj: Any) -> Optional[int]:
        return getattr(obj, "_wire_ticket", None)

    def _ticket_for(self, fut: AlFuture) -> int:
        """The wire name for a future: the ticket the server minted for it,
        or a fresh registration for derived futures (`.then` projections)
        that never crossed as an RPC reply."""
        t = self._wire_ref(fut)
        if t is None:
            t = self.server.register_future(self.token, fut)
            fut._wire_ticket = t
        return t

    # -- the verbs -----------------------------------------------------------
    def submit_send(self, core, array, *, name, block, key=None, payload=None):
        # The payload doubles as the attach fallback server-side, so the
        # bytes always cross (socket bytes are not bridge bytes: the session
        # counters that the parity check compares are engine-side). Frames
        # go shard-aligned whenever the client layout has a row-slab
        # geometry, letting the server decode shard-direct.
        from repro.core.relayout import shard_geometry

        host = np.asarray(array)
        geom = None
        sess = getattr(core, "session", None)
        if sess is not None and not core.engine_layout.cyclic:
            geom = shard_geometry(
                host.shape, host.dtype, core.client_layout, sess.mesh
            )
        reply = self._rpc(
            wire.T_SEND,
            {"__name": name, "__block": block, "__has_payload": payload is not None},
            array=host,
            geom=geom,
        )
        return self._take(reply)

    def submit_run(self, core, library, routine, args, params, *, block, out_shapes, out_dtype):
        try:
            payload = wire.encode_run_request(
                library,
                routine,
                args,
                params,
                block=block,
                out_shapes=out_shapes,
                out_dtype=out_dtype,
                ticket_of=self._ticket_for,
            )
            wire.pack_frame(wire.T_RUN, payload)  # prove the args frame
        except Exception as exc:  # noqa: BLE001 — unserializable args fail the
            # future, not the call site (loopback parity: the in-process path
            # hits the same codec inside the task).
            fut = AlFuture(label=f"run:{library}.{routine}:reject")
            fut._set_exception(exc)
            return fut
        return self._take(self._rpc(wire.T_RUN, payload))

    def submit_collect(self, core, h):
        req = self._collect_target(h)
        reply = self._rpc(wire.T_COLLECT, req)
        ticket = int(reply["__ticket"])
        return _TcpCollectFuture(self, ticket, self.server.take_future(self.token, ticket))

    def free(self, core, h):
        return self._take(self._rpc(wire.T_FREE, self._collect_target(h)))

    def _collect_target(self, h) -> Dict[str, Any]:
        if isinstance(h, _TcpCollectFuture):
            return {"__ticket": h._ticket}
        if isinstance(h, AlFuture):
            return {"__ticket": self._ticket_for(h)}
        return {"__h": h}  # AlMatrix/HandleRef: the codec frames it

    def barrier(self, core, timeout):
        self._rpc(wire.T_BARRIER, {"__timeout": timeout})

    def register_library(self, core, name, spec):
        self._rpc(wire.T_REGISTER, {"__name": name, "__spec": spec})
        return core.session.libraries[name]

    def close_session(self, core):
        try:
            self._rpc(wire.T_CLOSE, {})
        except (SessionError, ConnectionError, OSError):
            # Socket already dead: the server's disconnect path (or linger
            # expiry) owns the release; make it deterministic here.
            if self.token is not None and self.server.has_session(self.token):
                self.server._release(self.server._require(self.token), why="client stop")
        finally:
            self._close_sock()

    def wire_stats(self):
        return {
            "bytes_sent": self.bytes_sent,
            "bytes_received": self.bytes_received,
            "frames": self.frames,
            "vectored_writes": self.counters.get("vectored_writes", 0),
            "shard_direct_receives": 0,  # receives happen server-side
            "reassembly_receives": 0,
            "inflight": len(self._waiters),
            "max_inflight": self._max_inflight,
        }
