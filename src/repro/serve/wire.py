"""EngineServer + TcpTransport — the engine behind a real socket.

DESIGN.md §11. The paper's deployment is two processes bridged by a network:
Spark's driver speaks to the Alchemist driver over a socket, matrix payloads
cross between worker sets, and a dropped connection must return the client's
worker group to the pool. This module is that server for the reproduction:

- :class:`EngineServer` — a threaded TCP server wrapping one
  :class:`~repro.core.engine.AlchemistEngine`. Each accepted connection binds
  at most one session (CONNECT allocates it; HELLO with a session token
  re-binds an existing one after a drop). Requests are length-prefixed ALWF
  control frames (:mod:`repro.core.transport`) executed against an
  engine-side :class:`~repro.core.client.ClientCore` twin; replies are
  OK/ERR/ARRAY frames. A disconnect releases the bound session — its worker
  group returns to the pool — unless ``linger > 0`` grants a reconnect
  window for the token to re-bind within.
- :class:`TcpTransport` — the client half of the seam: the same five verbs
  as loopback, spoken over a localhost socket. Submission verbs return after
  the server *enqueues* (an integer ticket names the engine-side future);
  collect results are pulled with FETCH, which streams the array back in
  chunks.

Loopback-parity deployment: the server thread lives in the engine's process
(``ensure_server``), so handles and futures the RPCs name can be resolved to
the live in-process objects (``session_object``/``take_future``) while every
control frame and payload byte genuinely crosses the socket. The bridge-byte
accounting (``SessionStats``) runs engine-side in both transports, which is
what makes the loopback and TCP counters comparable — the wire benchmark's
parity check and CI's ``REPRO_TRANSPORT=tcp`` tier-1 run both lean on this.
A fully remote client would add a client-side handle cache; the protocol
already carries everything it needs (handles cross as HandleRefs, futures as
tickets, arrays as framed bytes).
"""

from __future__ import annotations

import itertools
import socket
import threading
import uuid
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core import transport as wire
from repro.core.errors import AlchemistError, SessionError, TaskError
from repro.core.futures import AlFuture
from repro.core.layouts import by_name as layout_by_name
from repro.core.params import HandleRef
from repro.core.transport import Transport

_SERVERS: Dict[int, "EngineServer"] = {}
_SERVERS_LOCK = threading.Lock()


def ensure_server(engine, **kwargs) -> "EngineServer":
    """The engine's singleton wire server, started on first use."""
    with _SERVERS_LOCK:
        srv = _SERVERS.get(id(engine))
        if srv is None or srv.closed:
            srv = EngineServer(engine, **kwargs)
            _SERVERS[id(engine)] = srv
        return srv


class _Bound:
    """One session's server-side state: the engine core twin, the ticket
    table naming its in-flight futures, and the reconnect bookkeeping."""

    def __init__(self, token: str, session, core):
        self.token = token
        self.session = session
        self.core = core
        self.futures: Dict[int, AlFuture] = {}
        self._tickets = itertools.count(1)
        self.lock = threading.Lock()
        self.released = False
        self.linger_timer: Optional[threading.Timer] = None

    def ticket(self, fut: AlFuture) -> int:
        with self.lock:
            t = next(self._tickets)
            self.futures[t] = fut
        return t

    def future(self, t: int) -> AlFuture:
        with self.lock:
            try:
                return self.futures[t]
            except KeyError:
                raise SessionError(f"unknown ticket {t} for session {self.session.id}") from None


class EngineServer:
    """Threaded TCP server wrapping an AlchemistEngine (DESIGN.md §11)."""

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 0, linger: float = 0.0):
        self.engine = engine
        self.linger = linger
        self.closed = False
        self._sock = socket.create_server((host, port))
        self.address: Tuple[str, int] = self._sock.getsockname()[:2]
        self._lock = threading.Lock()
        self._bound: Dict[str, _Bound] = {}
        self.stats = {
            "connections": 0,
            "disconnect_releases": 0,  # sessions torn down by a dropped socket
            "reconnects": 0,  # HELLO re-binds within the linger window
            "frames": 0,
            "bytes_in": 0,
            "bytes_out": 0,
        }
        self._accept = threading.Thread(
            target=self._accept_loop, name=f"wire-{self.address[1]}", daemon=True
        )
        self._accept.start()

    # -- in-process parity lookups (see module docstring) --------------------
    def session_object(self, token: str):
        return self._require(token).session

    def take_future(self, token: str, ticket: int) -> AlFuture:
        return self._require(token).future(ticket)

    def register_future(self, token: str, fut: AlFuture) -> int:
        """Admit an engine-side future the server did not itself mint into
        the session's ticket table (derived futures: `.then` projections the
        planner builds over RUN outputs). In-process parity only — a fully
        remote client would await the projection and reference the handle."""
        return self._require(token).ticket(fut)

    def _require(self, token: str) -> _Bound:
        with self._lock:
            try:
                return self._bound[token]
            except KeyError:
                raise SessionError(f"unknown or expired session token {token!r}") from None

    def has_session(self, token: str) -> bool:
        with self._lock:
            return token in self._bound

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Stop accepting, release every still-bound session."""
        self.closed = True
        try:
            self._sock.close()
        except OSError:
            pass
        with self._lock:
            bound = list(self._bound.values())
            self._bound.clear()
        for b in bound:
            self._release(b, why="server close")

    def _release(self, b: _Bound, why: str) -> None:
        with self._lock:
            if b.released:
                return
            b.released = True
            self._bound.pop(b.token, None)
            if b.linger_timer is not None:
                b.linger_timer.cancel()
        # engine.release drains the session queue and returns the worker
        # group to the pool in canonical order, waking queued connects.
        self.engine.release(b.session)

    # -- server loop ---------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self.closed:
            try:
                conn, _addr = self._sock.accept()
            except OSError:
                return  # listener closed
            self.stats["connections"] += 1
            threading.Thread(
                target=self._serve_connection,
                args=(conn,),
                daemon=True,
                name=f"wire-conn-{self.stats['connections']}",
            ).start()

    def _serve_connection(self, conn: socket.socket) -> None:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        bound: Optional[_Bound] = None
        explicit_close = False
        try:
            while True:
                try:
                    ftype, req, nread = wire.recv_frame(conn)
                except ConnectionError:
                    break  # peer vanished: disconnect semantics below
                self.stats["frames"] += 1
                self.stats["bytes_in"] += nread
                try:
                    bound, closed = self._dispatch(conn, ftype, req, bound)
                    if closed:
                        explicit_close = True
                        break
                except AlchemistError as exc:
                    self._reply(conn, wire.T_ERR, wire.error_payload(exc))
                except Exception as exc:  # noqa: BLE001 — map, never crash the loop
                    self._reply(conn, wire.T_ERR, wire.error_payload(exc))
        except (ConnectionError, OSError):
            pass  # reply write failed: same as a disconnect
        finally:
            try:
                conn.close()
            except OSError:
                pass
            if bound is not None and not explicit_close and not bound.released:
                if self.linger > 0:
                    # Reconnect window: keep the session bound; release only
                    # if no HELLO re-binds the token in time.
                    self._schedule_linger(bound)
                else:
                    self.stats["disconnect_releases"] += 1
                    self._release(bound, why="disconnect")

    def _schedule_linger(self, b: _Bound) -> None:
        def expire() -> None:
            with self._lock:
                if b.released or b.token not in self._bound:
                    return
            self.stats["disconnect_releases"] += 1
            self._release(b, why="linger expired")

        t = threading.Timer(self.linger, expire)
        t.daemon = True
        b.linger_timer = t
        t.start()

    def _reply(self, conn: socket.socket, ftype: int, payload: Dict[str, Any]) -> None:
        self.stats["bytes_out"] += wire.send_frame(conn, ftype, payload)

    # -- verb dispatch -------------------------------------------------------
    def _dispatch(
        self, conn: socket.socket, ftype: int, req: Dict[str, Any], bound: Optional[_Bound]
    ) -> Tuple[Optional[_Bound], bool]:
        if ftype == wire.T_HELLO:
            token = req.get("__token")
            if token:
                bound = self._require(str(token))
                if bound.linger_timer is not None:
                    bound.linger_timer.cancel()
                    bound.linger_timer = None
                self.stats["reconnects"] += 1
                self._reply(conn, wire.T_OK, {"__sid": bound.session.id})
            else:
                self._reply(conn, wire.T_OK, {})
            return bound, False

        if ftype == wire.T_CONNECT:
            if bound is not None:
                raise SessionError("connection already has a bound session")
            bound = self._connect(req)
            self._reply(conn, wire.T_OK, {"__token": bound.token, "__sid": bound.session.id})
            return bound, False

        if bound is None:
            raise SessionError(
                f"frame {wire.FRAME_NAMES.get(ftype, ftype)} before CONNECT/HELLO bound a session"
            )
        core = bound.core

        if ftype == wire.T_SEND:
            arr, nread = wire.recv_array(conn)
            self.stats["bytes_in"] += nread
            payload = arr if bool(req.get("__has_payload")) else None
            fut = core._local_submit_send(
                arr,
                name=str(req.get("__name") or ""),
                block=bool(req.get("__block")),
                key=None,
                payload=payload,
            )
            self._reply(conn, wire.T_OK, {"__ticket": bound.ticket(fut)})

        elif ftype == wire.T_RUN:
            dec = wire.decode_run_request(
                req, future_of=bound.future, handle_of=self._lenient_handle(bound)
            )
            fut = core._local_submit_run(
                dec["library"],
                dec["routine"],
                dec["args"],
                dec["params"],
                block=dec["block"],
                out_shapes=dec["out_shapes"],
                out_dtype=dec["out_dtype"],
            )
            self._reply(conn, wire.T_OK, {"__ticket": bound.ticket(fut)})

        elif ftype == wire.T_COLLECT:
            target = self._target(bound, req)
            fut = core._local_submit_collect(target)
            self._reply(conn, wire.T_OK, {"__ticket": bound.ticket(fut)})

        elif ftype == wire.T_FETCH:
            fut = bound.future(int(req["__ticket"]))
            timeout = req.get("__timeout")
            try:
                val = fut.result(None if timeout is None else float(timeout))
            except BaseException as exc:  # noqa: BLE001 — crosses as an ERR frame
                self._reply(conn, wire.T_ERR, wire.error_payload(exc))
                return bound, False
            out = np.asarray(val)
            header, chunks, _framed = wire.encode_array(out)
            conn.sendall(header)
            sent = len(header)
            for c in chunks:
                conn.sendall(len(c).to_bytes(8, "little"))
                conn.sendall(c)
                sent += 8 + len(c)
            self.stats["bytes_out"] += sent

        elif ftype == wire.T_FREE:
            target = self._target(bound, req)
            fut = core._local_free_async(target)
            self._reply(conn, wire.T_OK, {"__ticket": bound.ticket(fut)})

        elif ftype == wire.T_BARRIER:
            timeout = req.get("__timeout")
            bound.session.drain(None if timeout is None else float(timeout))
            self._reply(conn, wire.T_OK, {})

        elif ftype == wire.T_REGISTER:
            core._local_register_library(str(req["__name"]), str(req["__spec"]))
            self._reply(conn, wire.T_OK, {})

        elif ftype == wire.T_CLOSE:
            self._release(bound, why="client close")
            self._reply(conn, wire.T_OK, {})
            return bound, True

        else:
            raise SessionError(f"unknown wire frame type 0x{ftype:02x}")
        return bound, False

    def _connect(self, req: Dict[str, Any]) -> _Bound:
        from repro.core.client import ClientCore

        n_keys = int(req.get("__n_keys") or 0)
        datasets = [
            (
                tuple(req[f"__k{i}_shape"]),
                str(req[f"__k{i}_dtype"]),
                str(req[f"__k{i}_sha"]),
            )
            for i in range(n_keys)
        ]
        from repro.core.scheduler import PlacementRequest

        grid = req.get("__grid")
        workers = req.get("__workers")
        if "__deadline" in req or "__priority" in req:
            deadline = req.get("__deadline")
            placement = PlacementRequest(
                workers=None if workers is None else int(workers),
                grid=None if grid is None else tuple(int(d) for d in grid),
                priority=int(req.get("__priority") or 0),
                affinity=tuple(datasets),
                deadline=None if deadline is None else float(deadline),
                allow_shared=bool(req.get("__allow_shared", True)),
            )
        else:
            # v1 client (pre-scheduler wire): __queue/__timeout semantics.
            timeout = req.get("__timeout")
            placement = PlacementRequest(
                workers=None if workers is None else int(workers),
                grid=None if grid is None else tuple(int(d) for d in grid),
                affinity=tuple(datasets),
                deadline=(
                    (None if timeout is None else float(timeout))
                    if bool(req.get("__queue"))
                    else 0.0
                ),
            )
        session = self.engine.connect(
            name=str(req.get("__name") or "app"),
            hbm_budget=req.get("__hbm_budget"),
            placement=placement,
        )
        core = ClientCore._over_session(
            self.engine,
            session,
            layout_by_name(str(req.get("__clayout") or "row")),
            layout_by_name(str(req.get("__elayout") or "grid")),
        )
        b = _Bound(uuid.uuid4().hex, session, core)
        with self._lock:
            self._bound[b.token] = b
        return b

    def _target(self, bound: _Bound, req: Dict[str, Any]):
        """COLLECT/FREE target: a ticket naming an in-flight future, or a
        HandleRef resolved against the session table — leniently, so an
        unknown/foreign handle fails inside the task (the classic surface),
        not at the RPC."""
        if "__ticket" in req:
            return bound.future(int(req["__ticket"]))
        return self._lenient_handle(bound)(req["__h"])

    def _lenient_handle(self, bound: _Bound):
        def resolve(ref: HandleRef):
            live = bound.session.handles.get(ref.id)
            return live if live is not None else ref
        return resolve


class _TcpCollectFuture(AlFuture):
    """Client half of a wire collect: COLLECT enqueued engine-side (ticket),
    bytes pulled through FETCH on first ``result()``. ``done()``/callbacks
    observe the engine-side future (in-process parity, see module doc);
    the payload itself always crosses the socket exactly once."""

    def __init__(self, transport: "TcpTransport", ticket: int, engine_fut: AlFuture):
        super().__init__(label=f"collect:tcp:{ticket}")
        self._transport = transport
        self._ticket = ticket
        self._engine_fut = engine_fut
        self._fetch_lock = threading.Lock()

    def done(self) -> bool:
        return self._event.is_set() or self._engine_fut.done()

    def add_done_callback(self, fn) -> None:
        if self._event.is_set():
            fn(self)
            return
        self._engine_fut.add_done_callback(lambda _parent: fn(self))

    def _ensure_fetched(self, timeout: Optional[float]) -> None:
        """Pull the payload once. Task failures memoize into this future;
        a wait timeout (server-side ``result(timeout)`` expiring) raises
        without memoizing, so a later call can still succeed."""
        with self._fetch_lock:
            if self._event.is_set():
                return
            try:
                arr = self._transport._fetch(self._ticket, timeout)
            except TaskError as exc:
                if "not resolved within" in str(exc):
                    raise  # transient wait timeout crossing as TaskError
                self._set_exception(exc)
            except BaseException as exc:  # noqa: BLE001 — future API contract
                self._set_exception(exc)
            else:
                self._set_result(arr)

    def exception(self, timeout: Optional[float] = None) -> Optional[BaseException]:
        self._ensure_fetched(timeout)
        return super().exception(timeout)

    def result(self, timeout: Optional[float] = None) -> Any:
        self._ensure_fetched(timeout)
        return super().result(timeout)


class TcpTransport(Transport):
    """Client-side wire: the five verbs over one localhost TCP connection.

    One connection per client core (sessions stay independently socketed, so
    cross-session overlap survives the wire); a lock serializes RPCs on it.
    On a broken socket, a transport holding a session token transparently
    reconnects (HELLO + token) and retries the RPC once — the server side of
    the story is ``EngineServer`` linger.
    """

    name = "tcp"

    def __init__(self, server: Optional[EngineServer] = None):
        self._server = server
        self._sock: Optional[socket.socket] = None
        self._lock = threading.RLock()
        self.token: Optional[str] = None
        self.bytes_sent = 0
        self.bytes_received = 0
        self.frames = 0

    # -- connection management ----------------------------------------------
    @property
    def server(self) -> EngineServer:
        if self._server is None:
            raise SessionError("TcpTransport has no server; open_session first")
        return self._server

    def _dial(self) -> None:
        self._sock = socket.create_connection(self.server.address)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)

    def open_session(self, core, kwargs):
        if self._server is None:
            self._server = ensure_server(core.engine)
        self._dial()
        try:
            self._rpc(wire.T_HELLO, {"__token": None})
            reply = self._rpc(wire.T_CONNECT, self._connect_payload(core, kwargs))
        except BaseException:
            self._close_sock()
            raise
        self.token = str(reply["__token"])
        return self.server.session_object(self.token)

    def _connect_payload(self, core, kwargs) -> Dict[str, Any]:
        from repro.core.engine import _dataset_keys
        from repro.core.scheduler import PlacementRequest

        # CONNECT carries the declarative PlacementRequest (DESIGN.md §12).
        # Affinity payloads are hashed to content keys client-side — same
        # gate the engine applies (content_key reads every byte) — so the
        # wire never ships dataset bytes at connect time.
        request: PlacementRequest = kwargs.get("placement") or PlacementRequest(deadline=0.0)
        affinity = request.affinity or ()
        keys = _dataset_keys(affinity) if affinity and core.engine.residents.enabled else []
        payload: Dict[str, Any] = {
            "__name": kwargs.get("name") or "app",
            "__workers": request.workers,
            "__grid": None if request.grid is None else [int(d) for d in request.grid],
            "__hbm_budget": kwargs.get("hbm_budget"),
            "__priority": int(request.priority),
            "__deadline": None if request.deadline is None else float(request.deadline),
            "__allow_shared": bool(request.allow_shared),
            "__clayout": core.client_layout.name,
            "__elayout": core.engine_layout.name,
            "__n_keys": len(keys),
        }
        for i, (shape, dtype, sha) in enumerate(keys):
            payload[f"__k{i}_shape"] = [int(d) for d in shape]
            payload[f"__k{i}_dtype"] = str(dtype)
            payload[f"__k{i}_sha"] = str(sha)
        return payload

    def reconnect(self) -> None:
        """Re-dial and re-bind the session token (requires server linger or
        a still-open server binding)."""
        if self.token is None:
            raise SessionError("no session token to reconnect with")
        self._close_sock()
        self._dial()
        n = wire.send_frame(self._sock, wire.T_HELLO, {"__token": self.token})
        ftype, reply, nread = wire.recv_frame(self._sock)
        self.bytes_sent += n
        self.bytes_received += nread
        self.frames += 1
        if ftype == wire.T_ERR:
            raise wire.exception_from_payload(reply)

    def _close_sock(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    # -- RPC core ------------------------------------------------------------
    def _rpc(
        self,
        ftype: int,
        payload: Dict[str, Any],
        array: Optional[np.ndarray] = None,
        expect_array: bool = False,
    ):
        with self._lock:
            try:
                return self._rpc_once(ftype, payload, array, expect_array)
            except (ConnectionError, OSError):
                # Broken pipe / reset / EOF mid-RPC. With a token and a
                # server that still knows it (linger window, or the drop hit
                # us before the server noticed), re-bind and retry once.
                if self.token is None or not self.server.has_session(self.token):
                    raise SessionError(
                        "wire connection lost and session no longer bound "
                        "(server released it on disconnect)"
                    ) from None
                self.reconnect()
                return self._rpc_once(ftype, payload, array, expect_array)

    def _rpc_once(self, ftype, payload, array, expect_array):
        sock = self._sock
        if sock is None:
            raise ConnectionError("transport socket is closed")
        self.frames += 1
        self.bytes_sent += wire.send_frame(sock, ftype, payload)
        if array is not None:
            self.bytes_sent += wire.send_array(sock, array)
        rtype, reply, nread = wire.recv_frame(sock)
        self.bytes_received += nread
        if rtype == wire.T_ERR:
            raise wire.exception_from_payload(reply)
        if rtype == wire.T_ARRAY:
            if not expect_array:
                raise SessionError("unexpected ARRAY reply")
            arr, nbody = wire.recv_array_body(sock, reply)
            self.bytes_received += nbody
            return arr
        if expect_array:
            raise SessionError(f"expected ARRAY reply, got {wire.FRAME_NAMES.get(rtype, rtype)}")
        return reply

    def _fetch(self, ticket: int, timeout: Optional[float]):
        return self._rpc(
            wire.T_FETCH,
            {"__ticket": ticket, "__timeout": timeout},
            expect_array=True,
        )

    def _take(self, reply: Dict[str, Any]) -> AlFuture:
        ticket = int(reply["__ticket"])
        fut = self.server.take_future(self.token, ticket)
        fut._wire_ticket = ticket
        return fut

    @staticmethod
    def _wire_ref(obj: Any) -> Optional[int]:
        return getattr(obj, "_wire_ticket", None)

    def _ticket_for(self, fut: AlFuture) -> int:
        """The wire name for a future: the ticket the server minted for it,
        or a fresh registration for derived futures (`.then` projections)
        that never crossed as an RPC reply."""
        t = self._wire_ref(fut)
        if t is None:
            t = self.server.register_future(self.token, fut)
            fut._wire_ticket = t
        return t

    # -- the verbs -----------------------------------------------------------
    def submit_send(self, core, array, *, name, block, key=None, payload=None):
        # The payload doubles as the attach fallback server-side, so the
        # bytes always cross (socket bytes are not bridge bytes: the session
        # counters that the parity check compares are engine-side).
        host = np.asarray(array)
        reply = self._rpc(
            wire.T_SEND,
            {"__name": name, "__block": block, "__has_payload": payload is not None},
            array=host,
        )
        return self._take(reply)

    def submit_run(self, core, library, routine, args, params, *, block, out_shapes, out_dtype):
        try:
            payload = wire.encode_run_request(
                library,
                routine,
                args,
                params,
                block=block,
                out_shapes=out_shapes,
                out_dtype=out_dtype,
                ticket_of=self._ticket_for,
            )
            wire.pack_frame(wire.T_RUN, payload)  # prove the args frame
        except Exception as exc:  # noqa: BLE001 — unserializable args fail the
            # future, not the call site (loopback parity: the in-process path
            # hits the same codec inside the task).
            fut = AlFuture(label=f"run:{library}.{routine}:reject")
            fut._set_exception(exc)
            return fut
        return self._take(self._rpc(wire.T_RUN, payload))

    def submit_collect(self, core, h):
        req = self._collect_target(h)
        reply = self._rpc(wire.T_COLLECT, req)
        ticket = int(reply["__ticket"])
        return _TcpCollectFuture(self, ticket, self.server.take_future(self.token, ticket))

    def free(self, core, h):
        return self._take(self._rpc(wire.T_FREE, self._collect_target(h)))

    def _collect_target(self, h) -> Dict[str, Any]:
        if isinstance(h, _TcpCollectFuture):
            return {"__ticket": h._ticket}
        if isinstance(h, AlFuture):
            return {"__ticket": self._ticket_for(h)}
        return {"__h": h}  # AlMatrix/HandleRef: the codec frames it

    def barrier(self, core, timeout):
        self._rpc(wire.T_BARRIER, {"__timeout": timeout})

    def register_library(self, core, name, spec):
        self._rpc(wire.T_REGISTER, {"__name": name, "__spec": spec})
        return core.session.libraries[name]

    def close_session(self, core):
        try:
            self._rpc(wire.T_CLOSE, {})
        except (SessionError, ConnectionError, OSError):
            # Socket already dead: the server's disconnect path (or linger
            # expiry) owns the release; make it deterministic here.
            if self.token is not None and self.server.has_session(self.token):
                self.server._release(self.server._require(self.token), why="client stop")
        finally:
            self._close_sock()

    def wire_stats(self):
        return {
            "bytes_sent": self.bytes_sent,
            "bytes_received": self.bytes_received,
            "frames": self.frames,
        }
