"""Token sampling strategies (jit-safe)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array, key: Optional[jax.Array] = None) -> jax.Array:
    """logits [B, V] -> tokens [B]."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(
    logits: jax.Array,
    key: jax.Array,
    *,
    temperature: float = 1.0,
    top_k: Optional[int] = None,
) -> jax.Array:
    """logits [B, V] -> tokens [B] with temperature / top-k."""
    logits32 = logits.astype(jnp.float32) / max(temperature, 1e-6)
    if top_k is not None:
        kth = jax.lax.top_k(logits32, top_k)[0][..., -1:]
        logits32 = jnp.where(logits32 < kth, -jnp.inf, logits32)
    return jax.random.categorical(key, logits32, axis=-1).astype(jnp.int32)
