"""The shuffle — Spark's all-to-all exchange, with byte accounting.

Every record leaving its partition is counted as shuffled bytes (Spark
would serialize, spill and TCP-copy it; our ClusterModel charges network
time for exactly these bytes). Shuffles are also a *stage boundary*: the map
side must finish before the reduce side starts — so each shuffle bumps the
stage counter twice (map stage + reduce stage), matching Spark's DAG
scheduler behaviour.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Hashable, List, Sequence, Tuple


from repro.sparklike.rdd import RDD, nbytes_of as _nbytes


def shuffle_key_values(
    rdd: RDD,
    emit: Callable[[int, Any], Sequence[Tuple[Hashable, Any]]],
    num_out: int,
    partitioner: Callable[[Hashable], int],
) -> RDD:
    """Generic shuffle: map-side ``emit`` produces (key, value) records from
    each partition; records are hashed to ``num_out`` reduce partitions.

    Returns an RDD whose partitions are dicts ``key -> [values]``.
    """
    ctx = rdd.ctx

    # Map stage: produce per-output buckets from every input partition.
    def map_side(i: int, part: Any) -> List[List[Tuple[Hashable, Any]]]:
        buckets: List[List[Tuple[Hashable, Any]]] = [[] for _ in range(num_out)]
        for key, val in emit(i, part):
            buckets[partitioner(key) % num_out].append((key, val))
        return buckets

    bucketed = ctx.run_stage(rdd.partitions(), map_side, name="shuffleMap")

    # Byte accounting: every record that lands in a different partition index
    # than it started in is network traffic.
    moved = 0
    for src_idx, buckets in enumerate(bucketed):
        for dst_idx, bucket in enumerate(buckets):
            if dst_idx == src_idx % num_out and len(bucketed) == num_out:
                continue  # stayed local (only when partition counts line up)
            for _, val in bucket:
                moved += _nbytes(val)
    ctx.stats.shuffle_bytes += moved

    # Reduce stage: group by key within each output partition.
    def reduce_side(j: int, _: Any) -> Dict[Hashable, List[Any]]:
        grouped: Dict[Hashable, List[Any]] = {}
        for buckets in bucketed:
            for key, val in buckets[j]:
                grouped.setdefault(key, []).append(val)
        return grouped

    parts = ctx.run_stage(list(range(num_out)), reduce_side, name="shuffleReduce")
    return RDD(ctx, parts)
