"""A miniature, honest model of Spark's execution core.

What is kept (because the paper's §1.1 blames these for Spark's overheads):

- **Immutability**: every transformation materializes new partitions.
- **Bulk-synchronous stages**: a stage runs the same task over every
  partition and completes before the next stage starts; the driver schedules
  every task.
- **Driver round-trips**: ``reduce``/``collect`` bring data to the driver;
  broadcasts push data from it.
- **Accounting**: stages, tasks, shuffled/broadcast/collected bytes are all
  counted, and an analytic :class:`ClusterModel` maps the counts onto
  modeled wall-times for cluster-scale what-ifs (the paper's anti-scaling
  story lives in exactly these counts).

What is dropped: JVM, serialization codecs, fault tolerance/lineage
recovery, disk spill. Their *cost* is represented in ClusterModel's
per-task/per-stage constants, calibrated against the paper's own Table 1.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable, List, Optional, Sequence

import numpy as np

_RDD_IDS = itertools.count(1)


def nbytes_of(val: Any) -> int:
    """Payload bytes of an arbitrary record (nested tuples/dicts of arrays)."""
    if val is None:
        return 0
    if isinstance(val, (tuple, list)):
        return sum(nbytes_of(v) for v in val)
    if isinstance(val, dict):
        return sum(nbytes_of(v) for v in val.values())
    return int(np.asarray(val).nbytes)


@dataclasses.dataclass
class DriverStats:
    """Counted work — the inputs to the overhead model."""

    stages: int = 0
    tasks: int = 0
    shuffle_bytes: int = 0
    broadcast_bytes: int = 0
    collect_bytes: int = 0
    driver_syncs: int = 0
    wall_seconds: float = 0.0

    def merged(self, other: "DriverStats") -> "DriverStats":
        return DriverStats(
            stages=self.stages + other.stages,
            tasks=self.tasks + other.tasks,
            shuffle_bytes=self.shuffle_bytes + other.shuffle_bytes,
            broadcast_bytes=self.broadcast_bytes + other.broadcast_bytes,
            collect_bytes=self.collect_bytes + other.collect_bytes,
            driver_syncs=self.driver_syncs + other.driver_syncs,
            wall_seconds=self.wall_seconds + other.wall_seconds,
        )


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """Analytic time model for a simulated cluster.

    Defaults are calibrated to Spark-on-Cori behaviour reported in the paper
    and [2]: centralized scheduling costs ~5–10 ms/task at scale; stage
    barriers ~100 ms; TCP shuffle at NIC bandwidth.
    """

    num_executors: int = 8
    cores_per_executor: int = 32
    task_overhead_s: float = 0.005       # driver scheduling + dispatch per task
    stage_overhead_s: float = 0.1        # barrier + DAG bookkeeping per stage
    network_bw: float = 1.25e9           # bytes/s per executor (10 GbE-class)
    exec_flops: float = 5e10             # per-executor sustained GEMM flop/s
    driver_sync_s: float = 0.02          # per driver round-trip latency

    def modeled_seconds(self, stats: DriverStats, flops: float = 0.0) -> float:
        task_waves = stats.tasks / max(self.num_executors * self.cores_per_executor, 1)
        return (
            stats.stages * self.stage_overhead_s
            + stats.tasks * self.task_overhead_s  # driver dispatch is serial
            + stats.driver_syncs * self.driver_sync_s
            + (stats.shuffle_bytes + stats.broadcast_bytes + stats.collect_bytes)
            / (self.network_bw * max(self.num_executors, 1))
            + flops / (self.exec_flops * max(self.num_executors, 1))
            + task_waves * 0.0  # compute already covered by flops term
        )


class SparkLikeContext:
    """The driver. Owns executors (partition slots) and all scheduling."""

    def __init__(self, num_partitions: int = 8, cluster: Optional[ClusterModel] = None):
        self.default_parallelism = num_partitions
        self.cluster = cluster or ClusterModel(num_executors=num_partitions)
        self.stats = DriverStats()

    # -- RDD creation --------------------------------------------------------
    def parallelize(self, data: np.ndarray, num_partitions: Optional[int] = None) -> "RDD":
        p = num_partitions or self.default_parallelism
        parts = np.array_split(np.asarray(data), p, axis=0)
        return RDD(self, [np.ascontiguousarray(x) for x in parts])

    def empty(self) -> "RDD":
        return RDD(self, [])

    # -- scheduling ----------------------------------------------------------
    def run_stage(
        self,
        parts: Sequence[Any],
        fn: Callable[[int, Any], Any],
        *,
        name: str = "stage",
    ) -> List[Any]:
        """Run one bulk-synchronous stage: ``fn(partition_index, partition)``
        over every partition. Every call is one scheduled task."""
        t0 = time.perf_counter()
        out = [fn(i, p) for i, p in enumerate(parts)]
        self.stats.stages += 1
        self.stats.tasks += len(parts)
        self.stats.wall_seconds += time.perf_counter() - t0
        return out

    def broadcast(self, value: np.ndarray) -> np.ndarray:
        """Driver -> all executors. Costs bytes * num_executors."""
        arr = np.asarray(value)
        self.stats.broadcast_bytes += arr.nbytes * self.cluster.num_executors
        self.stats.driver_syncs += 1
        return arr

    def collect_to_driver(self, parts: Sequence[np.ndarray]) -> List[np.ndarray]:
        self.stats.collect_bytes += sum(nbytes_of(p) for p in parts)
        self.stats.driver_syncs += 1
        return list(parts)

    def modeled_seconds(self, flops: float = 0.0) -> float:
        return self.cluster.modeled_seconds(self.stats, flops)

    def reset_stats(self) -> DriverStats:
        old = self.stats
        self.stats = DriverStats()
        return old


class RDD:
    """Immutable row-partitioned dataset of numpy blocks."""

    def __init__(self, ctx: SparkLikeContext, partitions: List[Any]):
        self.ctx = ctx
        self._parts = partitions
        self.id = next(_RDD_IDS)

    @property
    def num_partitions(self) -> int:
        return len(self._parts)

    def partitions(self) -> List[Any]:
        return self._parts

    # -- transformations (each materializes new partitions: immutability) ----
    def map_partitions(self, fn: Callable[[Any], Any], name: str = "mapPartitions") -> "RDD":
        parts = self.ctx.run_stage(self._parts, lambda i, p: fn(p), name=name)
        return RDD(self.ctx, parts)

    def map_partitions_with_index(
        self, fn: Callable[[int, Any], Any], name: str = "mapPartitionsWithIndex"
    ) -> "RDD":
        parts = self.ctx.run_stage(self._parts, fn, name=name)
        return RDD(self.ctx, parts)

    def zip_partitions(self, other: "RDD", fn: Callable[[Any, Any], Any]) -> "RDD":
        if self.num_partitions != other.num_partitions:
            raise ValueError("zip_partitions requires co-partitioned RDDs")
        parts = self.ctx.run_stage(
            list(zip(self._parts, other._parts)), lambda i, pq: fn(pq[0], pq[1]),
            name="zipPartitions",
        )
        return RDD(self.ctx, parts)

    # -- actions --------------------------------------------------------------
    def reduce(self, fn: Callable[[Any, Any], Any]) -> Any:
        """Tree-reduce to the driver (one stage + one driver sync)."""
        partials = self.ctx.run_stage(self._parts, lambda i, p: p, name="reducePartials")
        gathered = self.ctx.collect_to_driver(partials)
        out = gathered[0]
        for g in gathered[1:]:
            out = fn(out, g)
        return out

    def collect(self) -> List[Any]:
        self.ctx.run_stage(self._parts, lambda i, p: p, name="collect")
        return self.ctx.collect_to_driver(self._parts)

    def cache(self) -> "RDD":
        return self  # always materialized in this miniature

    def count_bytes(self) -> int:
        return sum(nbytes_of(p) for p in self._parts)
