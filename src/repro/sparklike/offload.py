"""Auto-offload: sparklike MLlib calls rerouted through the Alchemist planner.

The follow-up paper ("Accelerating Large-Scale Data Analysis by Offloading to
High-Performance Computing Libraries using Alchemist", arXiv:1805.11800)
sells Alchemist as a *drop-in*: swap MLlib's matrix types for Alchemist-backed
ones and existing pipelines speed up without rewrites. This module is that
story for :mod:`repro.sparklike`:

    from repro.sparklike import mllib, offload

    with offload.offloaded(ac):
        u, s, v = mllib.compute_svd(ir, k)     # runs on the engine
        w = mllib.multiply(u, other)           # u never left the engine

Inside the context, ``mllib.compute_svd`` / ``mllib.multiply`` route through
the session's :class:`~repro.core.planner.OffloadPlanner`: matrix inputs are
deferred sends (content-deduped against the session's resident cache),
chained calls consume intermediates engine-side (elided bridge crossings),
and results come back as :class:`LazyRowMatrix` — an IndexedRowMatrix
look-alike whose rows stay resident until ``to_numpy()`` /
``to_indexed_row_matrix()`` explicitly collects them.

Outside the context everything is the pure sparklike baseline, unchanged.
"""

from __future__ import annotations

import contextlib
from typing import Any, Optional, Tuple, Union

import numpy as np

from repro.core.errors import SessionError
from repro.core.expr import LazyMatrix, peeked_state
from repro.core.planner import OffloadPlanner
from repro.sparklike.matrices import IndexedRowMatrix
from repro.sparklike.rdd import SparkLikeContext

# The active planner. A plain module global (not thread-local): the sparklike
# driver is single-threaded by construction, mirroring Spark's driver.
_ACTIVE: Optional[OffloadPlanner] = None


def _resolve_planner(ac_or_planner: Any) -> OffloadPlanner:
    """Accepts an OffloadPlanner, a v2 ``Session``, or the deprecated
    ``AlchemistContext`` shim — anything carrying a ``.planner``."""
    return (
        ac_or_planner
        if isinstance(ac_or_planner, OffloadPlanner)
        else ac_or_planner.planner
    )


def enable(ac_or_planner: Any) -> OffloadPlanner:
    """Route subsequent mllib calls through the given context's planner."""
    global _ACTIVE
    planner = _resolve_planner(ac_or_planner)
    _ACTIVE = planner
    return planner


def disable() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> Optional[OffloadPlanner]:
    """The planner mllib should offload to, or None for the pure baseline."""
    return _ACTIVE


_UNSET = object()


@contextlib.contextmanager
def offloaded(ac_or_planner: Any, hbm_budget: Any = _UNSET):
    """Scope within which sparklike mllib calls offload to Alchemist.

    ``hbm_budget`` (bytes, or None to lift this session's own request for
    the scope) overrides the session's budget *request* for the duration —
    the drop-in way to bound a pipeline's engine-resident footprint
    (DESIGN.md §7/§8). The governor is engine-wide and its effective budget
    is the min over the engine base and every session's request, so the
    override tightens (or relaxes) only this session's contribution: scopes
    in different sessions compose instead of clobbering one shared base, and
    the engine's own budget can never be lifted from a client scope. The
    previous request is restored on exit; already-spilled matrices stay
    spilled and refill on their next consumption as usual.
    """
    planner = _resolve_planner(ac_or_planner)
    session = planner.ac.session
    memgov = session.memgov
    prev_budget = memgov.requested_budget(session.id)
    overrode = hbm_budget is not _UNSET
    if overrode:
        # validates before activating the scope
        memgov.request_budget(session.id, hbm_budget)
    previous = _ACTIVE
    enable(planner)
    try:
        yield planner
    finally:
        if overrode:
            memgov.request_budget(session.id, prev_budget)
        if previous is not None:
            enable(previous)
        else:
            disable()


class LazyRowMatrix:
    """IndexedRowMatrix stand-in whose rows live on the Alchemist engine.

    Carries the same (num_rows, num_cols) metadata, chains into further
    offloaded mllib calls without crossing the bridge, and materializes
    client-side only on explicit request — the AlMatrix contract lifted to
    the sparklike API.
    """

    def __init__(self, lazy: LazyMatrix, num_rows: int, num_cols: int):
        self.lazy = lazy
        self.num_rows = int(num_rows)
        self.num_cols = int(num_cols)

    @property
    def planner(self) -> OffloadPlanner:
        return self.lazy.planner

    @property
    def state(self) -> str:
        """Where the rows physically are: ``deferred`` (not lowered yet),
        ``pending`` (transfer/compute queued), ``materialized`` (device-
        resident), ``spilled`` (governor moved them to the host store; the
        next consumption refills), ``failed``, or ``freed`` — the same
        vocabulary (and classifier) as the v2 ``AlArray.state``."""
        return peeked_state(self.planner.peek(self.lazy))

    def to_numpy(self) -> np.ndarray:
        """Collect: the explicit engine→client bridge crossing."""
        return np.asarray(self.lazy.collect())

    def to_indexed_row_matrix(
        self, ctx: SparkLikeContext, num_partitions: Optional[int] = None
    ) -> IndexedRowMatrix:
        """Convert back to a genuine (client-resident) IndexedRowMatrix."""
        return IndexedRowMatrix.from_numpy(ctx, self.to_numpy(), num_partitions)

    def __repr__(self) -> str:
        return f"LazyRowMatrix({self.num_rows}x{self.num_cols}, {self.lazy.expr!r})"


MatrixLike = Union[IndexedRowMatrix, LazyRowMatrix, np.ndarray]


def as_lazy(planner: OffloadPlanner, m: MatrixLike, name: str = "") -> LazyMatrix:
    """Adapt a sparklike/host matrix to a planner node.

    LazyRowMatrix passes its resident node through (no crossing);
    IndexedRowMatrix / ndarray become deferred sends, deduped by content so a
    matrix offloaded twice moves once.
    """
    if isinstance(m, LazyRowMatrix):
        if m.planner is not planner:
            raise SessionError(
                "LazyRowMatrix belongs to a different session's planner"
            )
        return m.lazy
    if isinstance(m, LazyMatrix):
        return m
    if isinstance(m, IndexedRowMatrix):
        # to_numpy() materializes a fresh private array — skip the defensive
        # snapshot copy the planner makes for caller-owned ndarrays.
        return planner.send(m.to_numpy(), name=name, snapshot=False)
    return planner.send(np.asarray(m), name=name)


def _dims(m: MatrixLike) -> Tuple[int, int]:
    if isinstance(m, (IndexedRowMatrix, LazyRowMatrix)):
        return m.num_rows, m.num_cols
    shape = getattr(m, "shape", None)
    if shape is None or len(shape) != 2:
        raise SessionError(f"expected a 2D matrix-like, got {type(m).__name__}")
    return int(shape[0]), int(shape[1])


def compute_svd(
    planner: OffloadPlanner,
    a: MatrixLike,
    k: int,
    *,
    oversample: int = 10,
    max_iters: Optional[int] = None,
    seed: int = 0,
) -> Tuple[LazyRowMatrix, np.ndarray, np.ndarray]:
    """Offloaded ``mllib.compute_svd``: one engine-side truncated SVD instead
    of a driver round-trip per Lanczos iteration (§1.1's overhead, gone).

    Matches the MLlib return contract: (U row-matrix, s [k], V [n, k]) — s
    and V are small driver-side results in MLlib too, so collecting them is
    faithful; U stays engine-resident as a :class:`LazyRowMatrix`.
    ``max_iters`` caps the Lanczos length like the baseline's: both compute
    ``L = min(k + oversample, n)``, so a cap maps onto the oversample.
    """
    m_rows, _ = _dims(a)
    if max_iters is not None:
        oversample = max(int(max_iters) - int(k), 0)
    la = as_lazy(planner, a, name="svd:A")
    u, s, v = planner.run(
        "elemental",
        "truncated_svd",
        la,
        n_outputs=3,
        k=int(k),
        oversample=int(oversample),
        seed=int(seed),
    )
    # Queue V's bridge crossing before blocking on the sigmas: both ride the
    # same FIFO behind the SVD task, so the two collects resolve in one
    # round trip instead of two sequential ones.
    v_future = planner.ac.collect_async(planner.lower(v))
    sigmas = np.asarray(planner.collect(s))
    v_mat = np.asarray(v_future.result())
    return LazyRowMatrix(u, m_rows, int(k)), sigmas, v_mat


def multiply(planner: OffloadPlanner, a: MatrixLike, b: MatrixLike) -> LazyRowMatrix:
    """Offloaded ``mllib.multiply``: one engine-side GEMM; no block explosion,
    no shuffle, and engine-resident operands (e.g. the U of a previous
    compute_svd) never cross the bridge."""
    (am, an), (bn, bk) = _dims(a), _dims(b)
    if an != bn:
        raise ValueError(f"dimension mismatch: {am}x{an} @ {bn}x{bk}")
    lc = planner.run(
        "elemental", "gemm", as_lazy(planner, a, name="gemm:A"), as_lazy(planner, b, name="gemm:B")
    )
    return LazyRowMatrix(lc, am, bk)
