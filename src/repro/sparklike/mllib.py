"""MLlib-style routines: computeSVD and matrix multiply — the paper's §4
Spark baselines.

``compute_svd`` reproduces MLlib's ``IndexedRowMatrix.computeSVD`` in
dist-eigs mode: **ARPACK runs on the driver**, and every Lanczos iteration
issues one distributed Gram matvec — broadcast v, one map stage of partial
AᵀAv products, one reduce to the driver. That per-iteration driver
round-trip is the synchronization overhead the paper's §1.1 highlights for
iterative algorithms ("the iterative nature of SVD algorithms leads to
substantial communication and synchronization overheads"), and it is why
Spark's overheads *anti-scale*: more executors = same number of driver
round-trips, each slower.

Both entry points also carry the arXiv:1805.11800 drop-in story: inside
``offload.offloaded(ac)`` they reroute through the session's lazy
:class:`~repro.core.planner.OffloadPlanner` — engine-side compute, deferred
sends deduped against the resident-matrix cache, and chained results staying
on the engine (see :mod:`repro.sparklike.offload`). Outside that scope they
are the unchanged pure-Spark baseline below.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.sparklike.matrices import IndexedRowMatrix


def _active_planner():
    # Imported lazily: ``offload`` pulls in the jax engine stack, and the
    # pure baseline must not.
    import sys

    mod = sys.modules.get("repro.sparklike.offload")
    return mod.active() if mod is not None else None


def gram_matvec(a: IndexedRowMatrix, v: np.ndarray) -> np.ndarray:
    """One distributed AᵀA v: broadcast + map stage + driver reduce."""
    ctx = a.ctx
    v_b = ctx.broadcast(v)
    partial = a.rdd.map_partitions(
        lambda part: part[1].T @ (part[1] @ v_b), name="gramMatvec"
    )
    return partial.reduce(lambda x, y: x + y)


def compute_svd(
    a: IndexedRowMatrix,
    k: int,
    *,
    oversample: int = 10,
    max_iters: int | None = None,
    seed: int = 0,
) -> Tuple[IndexedRowMatrix, np.ndarray, np.ndarray]:
    """MLlib-style truncated SVD: driver-side symmetric Lanczos on AᵀA with
    one distributed matvec (= one broadcast + one stage + one reduce) per
    iteration. Returns (U as IndexedRowMatrix, s [k], V [n, k]).

    With offload active, the whole decomposition runs engine-side in one
    planned call (U stays resident as a LazyRowMatrix).
    """
    planner = _active_planner()
    if planner is not None:
        from repro.sparklike import offload

        return offload.compute_svd(
            planner, a, k, oversample=oversample, max_iters=max_iters, seed=seed
        )
    n = a.num_cols
    L = min(k + oversample, n) if max_iters is None else max_iters
    rng = np.random.default_rng(seed)

    # --- driver-side Lanczos state (this IS how MLlib does it: ARPACK in the
    # driver JVM, matvecs on the cluster) ---
    q = rng.standard_normal(n)
    q /= np.linalg.norm(q)
    qs = [q]
    alphas: list[float] = []
    betas: list[float] = []

    for i in range(L):
        w = gram_matvec(a, qs[-1])                     # distributed round-trip
        alpha = float(qs[-1] @ w)
        w = w - alpha * qs[-1] - (betas[-1] * qs[-2] if betas else 0.0)
        # full reorthogonalization on the driver
        for qq in qs:
            w -= (qq @ w) * qq
        beta = float(np.linalg.norm(w))
        alphas.append(alpha)
        if beta < 1e-12 or i == L - 1:
            break
        betas.append(beta)
        qs.append(w / beta)

    t_mat = np.diag(alphas) + np.diag(betas, 1) + np.diag(betas, -1)
    evals, evecs = np.linalg.eigh(t_mat)
    order = np.argsort(evals)[::-1][:k]
    sigmas = np.sqrt(np.maximum(evals[order], 0.0))
    v_mat = np.stack(qs, axis=1) @ evecs[:, order]     # [n, k]

    # U = A V Σ⁻¹ — one more distributed pass, keeping row partitioning.
    ctx = a.ctx
    v_b = ctx.broadcast(v_mat)
    inv_s = np.where(sigmas > 1e-12, 1.0 / np.maximum(sigmas, 1e-12), 0.0)
    u_rdd = a.rdd.map_partitions(
        lambda part: (part[0], (part[1] @ v_b) * inv_s[None, :]), name="computeU"
    )
    u = IndexedRowMatrix(u_rdd, a.num_rows, k)
    return u, sigmas, v_mat


def multiply(
    a: IndexedRowMatrix, b: IndexedRowMatrix, *, block_size: int = 1024
) -> IndexedRowMatrix:
    """The paper's §4.1 Spark matmul recipe, verbatim:

        A.toBlockMatrix().multiply(B.toBlockMatrix()).toIndexedRowMatrix()

    With offload active, one engine-side GEMM instead — no explosion into
    (i, j, v) triples, no all-to-all shuffle, and engine-resident operands
    are consumed in place.
    """
    planner = _active_planner()
    if planner is not None:
        from repro.sparklike import offload

        return offload.multiply(planner, a, b)
    return (
        a.to_block_matrix(block_size)
        .multiply(b.to_block_matrix(block_size))
        .to_indexed_row_matrix()
    )


def gemm_flops(m: int, n: int, k: int) -> float:
    return 2.0 * m * n * k


def svd_flops(m: int, n: int, iters: int) -> float:
    """Gram-matvec flops per Lanczos run (2 passes over A per iteration)."""
    return 4.0 * m * n * iters
