"""IndexedRowMatrix / BlockMatrix — Spark MLlib's distributed matrix types.

The paper's §4.1 pins Spark's matmul problem on exactly this machinery:

  "Transposing a dense n x n row-distributed matrix A is accomplished by
   exploding the matrix into an RDD with n^2 rows of the form (i, j, A[i,j]),
   and then collecting this RDD back into an RDD of the columns of A. This
   operation is costly in terms of both memory usage, since RDDs are
   immutable, and communication, since it involves an all-to-all shuffle."

We reproduce the mechanics at block granularity (running a Python loop over
n^2 scalar triples would measure the interpreter, not the algorithm) but
charge the shuffle-byte accounting at **triple granularity** — 3 x 8 bytes
per matrix element, the (i, j, v) wire cost — so the modeled numbers carry
the true explosion penalty.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.sparklike.rdd import RDD, SparkLikeContext
from repro.sparklike.shuffle import shuffle_key_values

TRIPLE_BYTES_PER_ELEMENT = 24  # (int64 i, int64 j, float64 v)


class IndexedRowMatrix:
    """Row-partitioned dense matrix: partitions of (row_indices, row_block)."""

    def __init__(self, rdd: RDD, num_rows: int, num_cols: int):
        self.rdd = rdd
        self.num_rows = num_rows
        self.num_cols = num_cols

    @staticmethod
    def from_numpy(
        ctx: SparkLikeContext, a: np.ndarray, num_partitions: Optional[int] = None
    ) -> "IndexedRowMatrix":
        a = np.asarray(a, dtype=np.float64)
        p = num_partitions or ctx.default_parallelism
        splits = np.array_split(np.arange(a.shape[0]), p)
        parts = [(idx, np.ascontiguousarray(a[idx])) for idx in splits]
        return IndexedRowMatrix(RDD(ctx, parts), a.shape[0], a.shape[1])

    def to_numpy(self) -> np.ndarray:
        out = np.zeros((self.num_rows, self.num_cols))
        for idx, block in self.rdd.collect():
            out[idx] = block
        return out

    @property
    def ctx(self) -> SparkLikeContext:
        return self.rdd.ctx

    def to_block_matrix(self, block_size: int = 1024) -> "BlockMatrix":
        """The explode-and-shuffle conversion (§4.1).

        Each row fragment is emitted keyed by its destination block; shuffle
        bytes are charged at (i, j, v)-triple cost.
        """
        nbr = -(-self.num_rows // block_size)
        nbc = -(-self.num_cols // block_size)
        ctx = self.ctx

        def emit(i: int, part):
            idx, block = part
            records = []
            for bj in range(nbc):
                cols = block[:, bj * block_size : (bj + 1) * block_size]
                for bi in np.unique(idx // block_size):
                    sel = (idx // block_size) == bi
                    rows_in_block = idx[sel] - bi * block_size
                    records.append(
                        ((int(bi), bj), (rows_in_block, cols[sel]))
                    )
            return records

        shuffled = shuffle_key_values(
            self.rdd, emit, num_out=nbr * nbc, partitioner=lambda k: k[0] * nbc + k[1]
        )
        # Charge the triple-explosion premium over the raw bytes already
        # counted by the shuffle (which moved float64 payloads = 8 B/elem).
        ctx.stats.shuffle_bytes += (
            self.num_rows * self.num_cols * (TRIPLE_BYTES_PER_ELEMENT - 8)
        )

        def assemble(grouped: Dict) -> Dict[Tuple[int, int], np.ndarray]:
            blocks: Dict[Tuple[int, int], np.ndarray] = {}
            for (bi, bj), pieces in grouped.items():
                rows_here = min(block_size, self.num_rows - bi * block_size)
                cols_here = min(block_size, self.num_cols - bj * block_size)
                blk = np.zeros((rows_here, cols_here))
                for rows_in_block, vals in pieces:
                    blk[rows_in_block] = vals
                blocks[(bi, bj)] = blk
            return blocks

        block_rdd = shuffled.map_partitions(assemble, name="assembleBlocks")
        return BlockMatrix(block_rdd, self.num_rows, self.num_cols, block_size)


class BlockMatrix:
    """Block-partitioned matrix: partitions are dicts (bi, bj) -> block."""

    def __init__(self, rdd: RDD, num_rows: int, num_cols: int, block_size: int):
        self.rdd = rdd
        self.num_rows = num_rows
        self.num_cols = num_cols
        self.block_size = block_size

    @property
    def ctx(self) -> SparkLikeContext:
        return self.rdd.ctx

    @property
    def num_block_rows(self) -> int:
        return -(-self.num_rows // self.block_size)

    @property
    def num_block_cols(self) -> int:
        return -(-self.num_cols // self.block_size)

    def multiply(self, other: "BlockMatrix") -> "BlockMatrix":
        """Spark BlockMatrix.multiply: every A(i,j) is shuffled to all C(i,k)
        reducers, every B(j,k) to all C(i,k) reducers — the replication
        all-to-all that makes multi-node Spark GEMM fragile (§4.1)."""
        if self.num_cols != other.num_rows:
            raise ValueError(
                f"dimension mismatch: {self.num_rows}x{self.num_cols} @ "
                f"{other.num_rows}x{other.num_cols}"
            )
        if self.block_size != other.block_size:
            raise ValueError("block sizes must match")
        nbi, nbj = self.num_block_rows, self.num_block_cols
        nbk = other.num_block_cols
        ctx = self.ctx

        def emit_a(i: int, blocks: Dict) -> List:
            return [
                (((bi, bk)), ("A", bj, blk))
                for (bi, bj), blk in blocks.items()
                for bk in range(nbk)
            ]

        def emit_b(i: int, blocks: Dict) -> List:
            return [
                (((bi, bk)), ("B", bj, blk))
                for (bj, bk), blk in blocks.items()
                for bi in range(nbi)
            ]

        num_out = nbi * nbk
        def part_fn(k):
            return k[0] * nbk + k[1]
        a_shuf = shuffle_key_values(self.rdd, emit_a, num_out, part_fn)
        b_shuf = shuffle_key_values(other.rdd, emit_b, num_out, part_fn)

        def combine(a_grouped: Dict, b_grouped: Dict) -> Dict[Tuple[int, int], np.ndarray]:
            out: Dict[Tuple[int, int], np.ndarray] = {}
            for key in a_grouped:
                if key not in b_grouped:
                    continue
                a_pieces = {bj: blk for tag, bj, blk in a_grouped[key] if tag == "A"}
                b_pieces = {bj: blk for tag, bj, blk in b_grouped[key] if tag == "B"}
                acc = None
                for bj, a_blk in a_pieces.items():
                    if bj in b_pieces:
                        term = a_blk @ b_pieces[bj]
                        acc = term if acc is None else acc + term
                if acc is not None:
                    out[key] = acc
            return out

        c_rdd = a_shuf.zip_partitions(b_shuf, combine)
        return BlockMatrix(c_rdd, self.num_rows, other.num_cols, self.block_size)

    def to_indexed_row_matrix(self) -> IndexedRowMatrix:
        """Shuffle blocks back to row partitions (also costed)."""
        ctx = self.ctx
        p = ctx.default_parallelism
        rows_per_part = -(-self.num_rows // p)

        def emit(i: int, blocks: Dict) -> List:
            records = []
            for (bi, bj), blk in blocks.items():
                row0 = bi * self.block_size
                for dst in range(p):
                    lo, hi = dst * rows_per_part, min((dst + 1) * rows_per_part, self.num_rows)
                    sel_lo, sel_hi = max(lo - row0, 0), min(hi - row0, blk.shape[0])
                    if sel_lo < sel_hi:
                        records.append(
                            (dst, (row0 + sel_lo, bj * self.block_size, blk[sel_lo:sel_hi]))
                        )
            return records

        shuffled = shuffle_key_values(self.rdd, emit, p, lambda k: k)

        def assemble(grouped: Dict):
            if not grouped:
                return (np.zeros(0, dtype=np.int64), np.zeros((0, self.num_cols)))
            pieces = [v for vals in grouped.values() for v in vals]
            lo = min(r0 for r0, _, _ in pieces)
            hi = max(r0 + blk.shape[0] for r0, _, blk in pieces)
            out = np.zeros((hi - lo, self.num_cols))
            for r0, c0, blk in pieces:
                out[r0 - lo : r0 - lo + blk.shape[0], c0 : c0 + blk.shape[1]] = blk
            return (np.arange(lo, hi), out)

        rows = shuffled.map_partitions(assemble, name="assembleRows")
        return IndexedRowMatrix(rows, self.num_rows, self.num_cols)

    def to_numpy(self) -> np.ndarray:
        out = np.zeros((self.num_rows, self.num_cols))
        for blocks in self.rdd.collect():
            for (bi, bj), blk in blocks.items():
                out[
                    bi * self.block_size : bi * self.block_size + blk.shape[0],
                    bj * self.block_size : bj * self.block_size + blk.shape[1],
                ] = blk
        return out
