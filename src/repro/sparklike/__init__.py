"""sparklike — a faithful miniature of the Spark execution model.

This package is the paper's *comparison baseline* (the thing Alchemist
rescues you from), implemented honestly so the reproduction's Spark-side
numbers come from real mechanics, not guesses:

- ``rdd.py``      — immutable row-partitioned datasets, driver-scheduled
                    stages, per-stage/task overhead accounting.
- ``shuffle.py``  — the all-to-all shuffle primitive with byte accounting.
- ``matrices.py`` — ``IndexedRowMatrix`` / ``BlockMatrix`` with the
                    explode-into-(i, j, v)-triples conversion the paper
                    singles out (§4.1) as the reason Spark matmul is
                    memory-hungry and unreliable.
- ``mllib.py``    — MLlib-style ``computeSVD`` (ARPACK-on-the-driver with a
                    distributed matvec and a driver round-trip per
                    iteration) and ``BlockMatrix.multiply``.
- ``offload.py``  — the arXiv:1805.11800 drop-in: inside
                    ``offload.offloaded(ac)`` the mllib entry points reroute
                    through the session's lazy offload planner
                    (DESIGN.md §6); results stay engine-resident as
                    ``LazyRowMatrix`` until explicitly collected.

The cluster is simulated in-process: partitions are numpy arrays,
"executors" are slots, and the driver's bulk-synchronous stage scheduling
is what creates the overheads the paper measures. An analytic
:class:`~repro.sparklike.rdd.ClusterModel` converts the counted stages /
tasks / shuffled bytes into modeled times for the Cori-scale benchmark
tables; wall-clock on this container is also measured.
"""

from repro.sparklike.matrices import BlockMatrix, IndexedRowMatrix
from repro.sparklike.rdd import ClusterModel, RDD, SparkLikeContext

__all__ = [
    "RDD",
    "SparkLikeContext",
    "ClusterModel",
    "IndexedRowMatrix",
    "BlockMatrix",
    "LazyRowMatrix",
    "offload",
]


def __getattr__(name):
    # Lazy: ``offload`` pulls in repro.core (jax); the pure baseline above
    # must stay importable without touching the engine stack.
    if name in ("offload", "LazyRowMatrix"):
        import importlib

        mod = importlib.import_module("repro.sparklike.offload")
        globals()["offload"] = mod
        return mod if name == "offload" else mod.LazyRowMatrix
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
