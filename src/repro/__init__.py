"""repro: Alchemist-on-TPU — a JAX offload engine for distributed dense
linear algebra, embedded in a multi-pod training/serving framework.

Reproduction of: Gittens, Rothauge, et al., "Alchemist: An Apache Spark <=>
MPI Interface" (CS.DC 2018), adapted from Spark/MPI/Cori to JAX/XLA/TPU.

Public API (mirrors the paper's ACI, plus the async task-queue surface —
see DESIGN.md):

    from repro import AlchemistContext, AlchemistEngine, AlMatrix, AlFuture
"""

from repro.core.engine import AlchemistContext, AlchemistEngine
from repro.core.futures import AlFuture
from repro.core.handles import AlMatrix
from repro.core.layouts import GRID, REPLICATED, ROW, LayoutSpec

__version__ = "1.2.0"

__all__ = [
    "AlchemistContext",
    "AlchemistEngine",
    "AlFuture",
    "AlMatrix",
    "LayoutSpec",
    "ROW",
    "GRID",
    "REPLICATED",
]
