"""repro: Alchemist-on-TPU — a JAX offload engine for distributed dense
linear algebra, embedded in a multi-pod training/serving framework.

Reproduction of: Gittens, Rothauge, et al., "Alchemist: An Apache Spark <=>
MPI Interface" (CS.DC 2018), adapted from Spark/MPI/Cori to JAX/XLA/TPU.

Public API — the v2 client surface (DESIGN.md §9): one lazy-by-default
``connect()`` returning a :class:`Session` of uniform :class:`AlArray`
handles, with execution selected by a pluggable :class:`ExecutionPolicy`
(:class:`Eager` / :class:`Pipelined` / :class:`Planned`)::

    import repro

    engine = repro.AlchemistEngine()
    with repro.connect(engine, workers=4) as session:
        session.register_library("elemental", "repro.linalg.library:ElementalLib")
        a = session.send(A)
        u, s, v = session.run("elemental", "truncated_svd", a, n_outputs=3, k=8)
        U = u.data()           # the one explicit bridge crossing

The v1 :class:`AlchemistContext` (the paper's ACI, plus the async task-queue
surface) remains as a deprecation shim over the same transport core.
"""

from repro.core.client import AlArray, AlchemistContext, Session, connect
from repro.core.engine import AlchemistEngine
from repro.core.futures import AlFuture
from repro.core.handles import AlMatrix
from repro.core.layouts import GRID, REPLICATED, ROW, LayoutSpec
from repro.core.policy import Eager, ExecutionPolicy, Pipelined, Planned
from repro.core.scheduler import PlacementRequest

__version__ = "2.0.0"

__all__ = [
    # v2 surface (DESIGN.md §9, §12)
    "connect",
    "Session",
    "AlArray",
    "ExecutionPolicy",
    "Eager",
    "Pipelined",
    "Planned",
    "PlacementRequest",
    # engine + building blocks
    "AlchemistEngine",
    "AlFuture",
    "AlMatrix",
    "LayoutSpec",
    "ROW",
    "GRID",
    "REPLICATED",
    # deprecated v1 shim
    "AlchemistContext",
]
