"""roofline — v5e hardware model, HLO collective parser, three-term report."""

from repro.roofline.hw import TPUv5e
from repro.roofline.analysis import RooflineReport, analyze_compiled

__all__ = ["TPUv5e", "RooflineReport", "analyze_compiled"]
