"""Target hardware constants (TPU v5e), per the assignment:

  197 TFLOP/s bf16 per chip; 819 GB/s HBM bandwidth; ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TPUv5e:
    peak_flops_bf16: float = 197e12     # FLOP/s per chip
    hbm_bandwidth: float = 819e9        # bytes/s per chip
    ici_link_bandwidth: float = 50e9    # bytes/s per link (one direction)
    hbm_bytes: int = 16 * 1024**3       # 16 GiB per chip
    vmem_bytes: int = 128 * 1024**2     # ~128 MiB VMEM per chip (v5e)
    mxu_dim: int = 128


HW = TPUv5e()
