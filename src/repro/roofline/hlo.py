"""HLO-text collective parser.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but NOT collective
traffic, so we parse the post-SPMD HLO module text: every
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` instruction is located, its result shapes and
replica-group size extracted, and a ring-algorithm traffic model applied:

  all-gather          (g-1)/g * out_bytes      (out = gathered buffer)
  reduce-scatter      (g-1)   * out_bytes      (in = g * out)
  all-reduce          2(g-1)/g * out_bytes     (reduce-scatter + all-gather)
  all-to-all          (g-1)/g * out_bytes
  collective-permute  out_bytes

All quantities are per-device (the module is the per-device SPMD program).
The dry-run lowers models with scans fully unrolled, so the flat text parse
sees every layer (no while-loop trip-count ambiguity); a safety check
reports whether any ``while`` op remains.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
# instruction line: "%name = <result-shapes> <opcode>(<operands>), attrs"
_INSTR_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def shape_bytes(shape_text: str) -> int:
    """Sum byte sizes of every dtype[shape] token in a result string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first_group = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(first_group), 1)
    return default


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    out_bytes: int
    group_size: int
    traffic_bytes: int
    line: str


@dataclasses.dataclass
class CollectiveSummary:
    ops: List[CollectiveOp]
    has_while: bool

    @property
    def total_traffic(self) -> int:
        return sum(o.traffic_bytes for o in self.ops)

    def by_kind(self) -> Dict[str, Tuple[int, int]]:
        """kind -> (count, traffic bytes)."""
        out: Dict[str, Tuple[int, int]] = {}
        for o in self.ops:
            c, b = out.get(o.kind, (0, 0))
            out[o.kind] = (c + 1, b + o.traffic_bytes)
        return out


def _traffic(kind: str, out_bytes: int, g: int) -> int:
    if g <= 1:
        return 0
    if kind == "all-gather":
        return int(out_bytes * (g - 1) / g)
    if kind == "reduce-scatter":
        return int(out_bytes * (g - 1))
    if kind == "all-reduce":
        return int(2 * out_bytes * (g - 1) / g)
    if kind == "all-to-all":
        return int(out_bytes * (g - 1) / g)
    if kind == "collective-permute":
        return out_bytes
    return 0


def parse_collectives(hlo_text: str, *, default_group: int) -> CollectiveSummary:
    ops: List[CollectiveOp] = []
    seen_started = set()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        # avoid double counting start/done pairs: skip "-done" lines
        if f"{m.group(2)}-done(" in line:
            continue
        shape_text, kind = m.group(1), m.group(2)
        out_b = shape_bytes(shape_text)
        g = _group_size(line, default_group)
        ops.append(
            CollectiveOp(
                kind=kind,
                out_bytes=out_b,
                group_size=g,
                traffic_bytes=_traffic(kind, out_b, g),
                line=line.strip()[:160],
            )
        )
    has_while = bool(re.search(r"\bwhile\(", hlo_text))
    return CollectiveSummary(ops=ops, has_while=has_while)
