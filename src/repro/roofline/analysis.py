"""Three-term roofline report from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / peak_FLOP/s            (per chip)
    memory term     = HLO_bytes / HBM_bw                 (per chip)
    collective term = collective_traffic / ICI_link_bw   (per chip)

``cost_analysis()`` runs on the post-SPMD per-device module, so its FLOPs /
bytes are already per-chip; dividing by per-chip peaks is equivalent to the
assignment's global/(chips x peak) formulation. Collective traffic comes
from :mod:`repro.roofline.hlo`.

Also reported: MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE, 2·N·D for
inference) and the usefulness ratio MODEL_FLOPS / HLO_FLOPs — remat and
dispatch waste show up here.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

from repro.configs.base import ArchConfig, InputShape
from repro.models.registry import effective_seq
from repro.roofline.hlo import parse_collectives
from repro.roofline.hw import HW, TPUv5e


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int

    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float

    compute_seconds: float
    memory_seconds: float
    collective_seconds: float
    dominant: str

    model_flops_global: float
    useful_flops_ratio: float        # model flops / compiled flops (global)

    collectives_by_kind: Dict[str, Any]
    has_while: bool

    # memory_analysis fields (bytes, per device)
    argument_bytes: int = 0
    output_bytes: int = 0
    temp_bytes: int = 0
    peak_bytes: int = 0

    lower_seconds: float = 0.0
    compile_seconds: float = 0.0

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @property
    def bound(self) -> str:
        return self.dominant


def model_flops(cfg: ArchConfig, shape: InputShape) -> float:
    """MODEL_FLOPS per step: 6·N·D for training, 2·N·D for inference
    (N = active params, D = tokens processed)."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * effective_seq(cfg, shape)
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * effective_seq(cfg, shape)
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze_compiled(
    compiled,
    *,
    cfg: ArchConfig,
    shape: InputShape,
    mesh_desc: str,
    n_devices: int,
    hw: TPUv5e = HW,
    lower_seconds: float = 0.0,
    compile_seconds: float = 0.0,
) -> RooflineReport:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax 0.4.x: one dict per program
        ca = ca[0] if ca else {}
    flops_pd = float(ca.get("flops", 0.0))
    bytes_pd = float(ca.get("bytes accessed", 0.0))

    text = compiled.as_text()
    coll = parse_collectives(text, default_group=n_devices)
    coll_pd = float(coll.total_traffic)

    compute_s = flops_pd / hw.peak_flops_bf16
    memory_s = bytes_pd / hw.hbm_bandwidth
    coll_s = coll_pd / hw.ici_link_bandwidth
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    compiled_global = flops_pd * n_devices
    ratio = mf / compiled_global if compiled_global else 0.0

    mem: Dict[str, int] = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": int(
                getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                + getattr(ma, "temp_size_in_bytes", 0)
            ),
        }
    except Exception:
        pass

    return RooflineReport(
        arch=cfg.arch_id,
        shape=shape.name,
        mesh=mesh_desc,
        n_devices=n_devices,
        flops_per_device=flops_pd,
        hbm_bytes_per_device=bytes_pd,
        collective_bytes_per_device=coll_pd,
        compute_seconds=compute_s,
        memory_seconds=memory_s,
        collective_seconds=coll_s,
        dominant=dominant,
        model_flops_global=mf,
        useful_flops_ratio=ratio,
        collectives_by_kind={k: list(v) for k, v in coll.by_kind().items()},
        has_while=coll.has_while,
        lower_seconds=lower_seconds,
        compile_seconds=compile_seconds,
        **mem,
    )
