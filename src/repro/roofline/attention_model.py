"""Analytic flash-attention roofline terms.

Why this exists: the dry-run runs on the CPU backend, where attention lowers
to XLA einsums. A materialized [L, L] score tensor makes cost_analysis
report HBM traffic ~14x higher than the Pallas flash kernel the TPU target
actually runs (the kernel streams K/V tiles through VMEM). So the dry-run's
cost-fit variants replace attention with an O(L·D) stub (exact fit of
everything-but-attention) and THIS module adds attention back with the exact
arithmetic of the kernel we ship (kernels/flash_attention.py):

FLOPs per layer (forward): 4 · B · Lq · Lk_eff · Hq · hd
  (QKᵀ and PV are each 2·B·Hq·Lq·Lk·hd; causal halves Lk_eff; sliding
   window caps it at W)
HBM bytes per layer (forward), streaming model with q-block bq:
  read Q + write O:        2 · B · Hq · Lq · hd · itemsize
  read K,V (per q-block):  2 · B · Hkv · Lk_eff · hd · itemsize · nq_blocks
Backward = 2x forward FLOPs (dQ,dK,dV recompute included at 2.5x in
practice; we use the standard 2x + 1x remat-forward when remat is on).
Collectives: none — attention partitions over batch (and heads when they
divide the tensor axis); no cross-shard reduction is required.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.configs.base import ArchConfig, InputShape
from repro.kernels.flash_attention import DEFAULT_BQ
from repro.models.registry import effective_seq


@dataclasses.dataclass(frozen=True)
class AttnTerms:
    flops_global: float
    hbm_bytes_global: float

    def per_device(self, n_batch_shards: int, head_shards: int) -> Tuple[float, float]:
        div = max(n_batch_shards * head_shards, 1)
        return self.flops_global / div, self.hbm_bytes_global / div


def _layer_terms(
    b: int, lq: int, lk: int, hq: int, hkv: int, hd: int,
    *, causal: bool, window: Optional[int], itemsize: int = 2, bq: int = DEFAULT_BQ,
) -> AttnTerms:
    lk_eff = lk / 2 if causal else lk
    if window is not None:
        lk_eff = min(lk_eff, window)
    flops = 4.0 * b * lq * lk_eff * hq * hd
    nq = max(lq // min(bq, lq), 1)
    bytes_qo = 2.0 * b * hq * lq * hd * itemsize
    bytes_kv = 2.0 * b * hkv * lk_eff * hd * itemsize * nq
    return AttnTerms(flops_global=flops, hbm_bytes_global=bytes_qo + bytes_kv)


def _num_attn_layers(cfg: ArchConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_period
    return cfg.n_layers


def attention_roofline(
    cfg: ArchConfig,
    shape: InputShape,
    *,
    remat: bool = True,
) -> AttnTerms:
    """Global analytic flash-attention terms for one step of (cfg, shape).

    Covers self-attention of every attention layer plus whisper's
    encoder self-attention and decoder cross-attention. Decode shapes get
    no correction here (their direct cached-attention HLO is already
    kernel-faithful)."""
    if shape.kind == "decode":
        return AttnTerms(0.0, 0.0)

    b = shape.global_batch
    seq = effective_seq(cfg, shape)
    hd = cfg.head_dim or 0
    n_attn = _num_attn_layers(cfg)
    window = cfg.sliding_window if shape.name == "long_500k" else None

    flops = 0.0
    hbm = 0.0
    if n_attn and hd:
        per = _layer_terms(
            b, seq, seq, cfg.n_heads, cfg.n_kv_heads, hd, causal=True, window=window
        )
        flops += per.flops_global * n_attn
        hbm += per.hbm_bytes_global * n_attn

    if cfg.is_enc_dec:
        enc = _layer_terms(
            b, cfg.encoder_seq, cfg.encoder_seq, cfg.n_heads, cfg.n_kv_heads, hd,
            causal=False, window=None,
        )
        cross = _layer_terms(
            b, seq, cfg.encoder_seq, cfg.n_heads, cfg.n_kv_heads, hd,
            causal=False, window=None,
        )
        flops += enc.flops_global * cfg.encoder_layers + cross.flops_global * cfg.n_layers
        hbm += enc.hbm_bytes_global * cfg.encoder_layers + cross.hbm_bytes_global * cfg.n_layers

    if shape.kind == "train":
        # fwd + bwd(2x) + remat re-forward(1x)
        mult = 4.0 if remat else 3.0
        flops *= mult
        hbm *= mult
    return AttnTerms(flops_global=flops, hbm_bytes_global=hbm)


def attention_shards(
    cfg: ArchConfig, mesh_shape: Tuple[int, ...], axis_names: Tuple[str, ...]
) -> Tuple[int, int]:
    """(batch_shards, head_shards) the attention work divides over."""
    sizes = dict(zip(axis_names, mesh_shape))
    batch_shards = sizes.get("pod", 1) * sizes.get("data", 1)
    tensor = sizes.get("model", 1)
    head_shards = tensor if (cfg.n_heads and cfg.n_heads % tensor == 0) else 1
    return batch_shards, head_shards
